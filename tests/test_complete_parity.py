"""Batched-completion-drain regression tier.

PR 4 vectorized the scheduling half of the closed loop; this tier pins the
completion half. The batched drain (`EngineConfig.wave_complete`: fabric
delivers same-timestamp completion runs in one call, telemetry EWMAs update
through `TelemetryStore.on_complete_many`, failure fan-out retries flush
through one batched post) must be a pure *cost* change: every scenario
outcome has to be bit-identical to the per-completion scalar drain. These
tests pin that end-to-end across the whole scenario library, pin the
batched EWMA update against the scalar loop with a no-optional-deps seeded
sweep (the hypothesis twin lives in tests/test_properties.py), and cover
the fabric's drain grouping plus the adaptive WAVE_MIN tuner.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    Fabric,
    FabricSpec,
    TelemetryStore,
    TentEngine,
    Topology,
)
from repro.core.engine import WAVE_MIN, WAVE_MIN_CEIL, WAVE_MIN_FLOOR
from repro.scenarios import SCENARIOS, ScenarioRunner, get

# observables of the drain/dispatch *mechanism* itself — legitimately
# mode-dependent (the scalar drain never forms batches, and the adaptive
# crossover feeds on batch sizes), unlike every data-plane metric
MODE_DEPENDENT_EXTRAS = ("waves", "completion_batches")


def _policies(spec) -> dict:
    doc = ScenarioRunner(spec).run().to_dict()
    for rep in doc["policies"].values():
        for key in MODE_DEPENDENT_EXTRAS:
            rep["extra"].pop(key, None)
    return doc["policies"]


class TestWaveCompleteBitIdentity:
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_reports_identical_across_drain_toggle(self, name):
        """wave_complete on vs off over the full scenario library: every
        metric of every policy — byte counts, makespans, latency
        percentiles, retries, exclusions, per-rail byte maps, the
        completions-drained totals — must match exactly (same per-completion
        feedback => same decisions => same fabric event sequence)."""
        spec = get(name)
        on = _policies(spec)
        off = _policies(dataclasses.replace(
            spec,
            engine=dataclasses.replace(spec.engine, wave_complete=False)))
        assert on == off

    def test_pinned_wave_min_keeps_reports_identical(self):
        """The crossover is a pure cost knob: pinning it to either extreme
        must not move a single report metric."""
        spec = get("single_rail_flap")
        base = _policies(spec)
        for pin in (1, WAVE_MIN_CEIL * 4):
            pinned = _policies(dataclasses.replace(
                spec, engine=dataclasses.replace(spec.engine, wave_min=pin)))
            assert pinned == base


# ---------------------------------------------------------------------------
# on_complete_many vs looped on_complete: seeded randomized sweep (runs with
# no optional deps so every environment checks the bit-equality; the
# hypothesis twin in tests/test_properties.py explores adversarially)
# ---------------------------------------------------------------------------


def _seeded_store(rng, n_links):
    from repro.core.topology import LinkDesc
    from repro.core.types import LinkClass

    store = TelemetryStore()
    for i in range(n_links):
        desc = LinkDesc(link_id=i, node=0, link_class=LinkClass.RDMA,
                        index=i, numa=0, bandwidth=float(rng.choice([25e9, 1e9])),
                        base_latency=5e-6)
        tl = store.ensure(desc)
        tl.queued_bytes = int(rng.integers(0, 1 << 30))
        tl.beta0 = float(rng.uniform(0.0, 1e-2))
        tl.beta1 = float(rng.uniform(0.05, 50.0))
        tl.ewma_service_time = float(rng.uniform(0.0, 1.0))
    return store


class TestOnCompleteManySweep:
    def test_batched_update_bit_equals_scalar_loop_randomized(self):
        rng = np.random.default_rng(11)
        arrs = ("beta0_arr", "beta1_arr", "queued_arr", "ewma_service_arr",
                "completions_arr")
        for case in range(300):
            n_links = int(rng.integers(1, 7))
            seed = int(rng.integers(0, 1 << 30))
            a = _seeded_store(np.random.default_rng(seed), n_links)
            b = _seeded_store(np.random.default_rng(seed), n_links)
            m = int(rng.integers(1, 40))
            # heavy slot repetition on purpose: EWMA order sensitivity
            slots = rng.integers(0, n_links, size=m)
            lengths = rng.integers(0, 1 << 22, size=m)
            queued_at = rng.integers(0, 1 << 24, size=m)
            t_obs = rng.uniform(0.0, 5.0, size=m)
            for k in range(m):
                a._views[int(slots[k])].on_complete(
                    int(lengths[k]), int(queued_at[k]), float(t_obs[k]))
            b.on_complete_many(slots, lengths, queued_at, t_obs)
            for name in arrs:
                x, y = getattr(a, name)[:a.n], getattr(b, name)[:b.n]
                assert (x == y).all(), f"case {case} {name}: {x} != {y}"

    def test_zero_normalized_load_skips_beta1(self):
        """x == 0 (empty queue, zero-length sample) must leave beta1 alone
        and still apply the beta0/ewma updates — exactly like the scalar
        guard."""
        rng = np.random.default_rng(3)
        a = _seeded_store(np.random.default_rng(5), 2)
        b = _seeded_store(np.random.default_rng(5), 2)
        batch = [(0, 0, 0, 0.25), (1, 4096, 64, 0.5), (0, 0, 0, 0.125)]
        for slot, L, qas, tob in batch:
            a._views[slot].on_complete(L, qas, tob)
        b.on_complete_many(*(np.asarray(col) for col in zip(*batch)))
        assert (a.beta1_arr[:2] == b.beta1_arr[:2]).all()
        assert (a.beta0_arr[:2] == b.beta0_arr[:2]).all()
        assert (a.ewma_service_arr[:2] == b.ewma_service_arr[:2]).all()
        del rng


# ---------------------------------------------------------------------------
# Fabric drain grouping mechanics
# ---------------------------------------------------------------------------


def _quiet_fabric(jitter=0.0):
    return Fabric(Topology(FabricSpec()), seed=0, jitter=jitter)


class TestFabricCompletionBatching:
    def test_same_timestamp_completions_arrive_as_one_batch(self):
        fab = _quiet_fabric()
        topo = fab.topology
        lids = [topo.rdma_nic(0, i).link_id for i in range(4)]
        batches = []

        def cb(*a):  # shared tagged callback object
            raise AssertionError("sink should swallow batched deliveries")

        fab.register_completion_sink(cb, lambda ops, now: batches.append(
            ([op.tag for op in ops], now)))
        # same nbytes on four idle identical links: identical end timestamps
        fab.post_many([(lid, None, 4096, 0.0, 1.0, i)
                       for i, lid in enumerate(lids)], cb)
        fab.run_until_idle()
        assert batches == [([0, 1, 2, 3], batches[0][1])]

    def test_distinct_timestamps_stay_separate_batches(self):
        fab = _quiet_fabric()
        lid = fab.topology.rdma_nic(0, 0).link_id
        batches = []

        def cb(*a):
            raise AssertionError

        fab.register_completion_sink(cb, lambda ops, now: batches.append(
            [op.tag for op in ops]))
        # both ops serialize on one link -> distinct ends -> two batches
        fab.post_many([(lid, None, 4096, 0.0, 1.0, "a"),
                       (lid, None, 4096, 0.0, 1.0, "b")], cb)
        fab.run_until_idle()
        assert batches == [["a"], ["b"]]

    def test_unregistered_callbacks_deliver_per_op(self):
        fab = _quiet_fabric()
        topo = fab.topology
        lids = [topo.rdma_nic(0, i).link_id for i in range(2)]
        got = []
        fab.post_many([(lid, None, 4096, 0.0, 1.0, i)
                       for i, lid in enumerate(lids)],
                      lambda tag, ok, t0, t1, err: got.append((tag, ok)))
        fab.run_until_idle()
        assert got == [(0, True), (1, True)]

    def test_batched_drain_marks_mid_failures(self):
        """An op whose link fails between posting and completion must arrive
        in the batch with failed=True (the engine's batched retry handler
        keys off it)."""
        fab = _quiet_fabric()
        topo = fab.topology
        good = topo.rdma_nic(0, 0).link_id
        bad = topo.rdma_nic(0, 1).link_id
        seen = []

        def cb(*a):
            raise AssertionError

        fab.register_completion_sink(
            cb, lambda ops, now: seen.extend((op.tag, op.failed) for op in ops))
        fab.post_many([(good, None, 4096, 0.0, 1.0, "ok"),
                       (bad, None, 4096, 0.0, 1.0, "dead")], cb)
        # window opens after posting, covering the bad op's whole service
        end = fab.links[bad].busy_until + 1.0
        fab.links[bad].fail_windows.append((0.0, end))
        fab.run_until_idle()
        assert ("ok", False) in seen
        assert ("dead", True) in seen


# ---------------------------------------------------------------------------
# Adaptive WAVE_MIN
# ---------------------------------------------------------------------------


def _host(node, numa=0):
    from repro.core import Location, MemoryKind

    return Location(node=node, kind=MemoryKind.HOST_DRAM, device=numa, numa=numa)


class TestAdaptiveWaveMin:
    def test_burst_lowers_crossover_to_floor(self):
        eng = TentEngine(
            FabricSpec(), config=EngineConfig(max_inflight=4096), seed=3)
        assert eng.wave_min == WAVE_MIN  # neutral until traffic is observed
        src = eng.register_segment(_host(0), 64 << 20, materialize=False)
        dst = eng.register_segment(_host(1), 64 << 20, materialize=False)
        assert eng.transfer_sync(src.segment_id, 0, dst.segment_id, 0, 64 << 20).ok
        assert eng.wave_min == WAVE_MIN_FLOOR
        assert eng.waves >= 1

    def test_single_slice_trickle_raises_crossover_to_ceiling(self):
        eng = TentEngine(FabricSpec(), seed=3)
        src = eng.register_segment(_host(0), 4096, materialize=False)
        dst = eng.register_segment(_host(1), 4096, materialize=False)
        for _ in range(6):
            assert eng.transfer_sync(src.segment_id, 0, dst.segment_id, 0, 4096).ok
        assert eng.wave_min == WAVE_MIN_CEIL
        assert eng.waves == 0  # trickle runs must stay on the scalar path

    def test_config_pin_disables_tuning(self):
        eng = TentEngine(
            FabricSpec(),
            config=EngineConfig(max_inflight=4096, wave_min=WAVE_MIN_CEIL),
            seed=3)
        src = eng.register_segment(_host(0), 64 << 20, materialize=False)
        dst = eng.register_segment(_host(1), 64 << 20, materialize=False)
        assert eng.transfer_sync(src.segment_id, 0, dst.segment_id, 0, 64 << 20).ok
        assert eng.wave_min == WAVE_MIN_CEIL  # pinned, burst notwithstanding

    def test_phantom_transfer_still_bounds_checked(self):
        """Skipping the phantom byte copy in the drain loop must not lose
        bounds validation: out-of-range offsets now fail loudly at submit
        time (for phantom segments the completion-time read/write this
        replaced was the only check)."""
        eng = TentEngine(FabricSpec(), seed=0)
        src = eng.register_segment(_host(0), 1 << 20, materialize=False)
        dst = eng.register_segment(_host(1), 1 << 20, materialize=False)
        with pytest.raises(IndexError, match="out of bounds"):
            eng.transfer_sync(
                src.segment_id, 0, dst.segment_id, 1 << 20, 1 << 20)
        with pytest.raises(IndexError, match="out of bounds"):
            eng.transfer_sync(
                src.segment_id, 1, dst.segment_id, 0, 1 << 20)  # src side too

    def test_drain_batches_counted(self):
        eng = TentEngine(
            FabricSpec(), config=EngineConfig(max_inflight=4096), seed=3)
        src = eng.register_segment(_host(0), 8 << 20, materialize=False)
        dst = eng.register_segment(_host(1), 8 << 20, materialize=False)
        assert eng.transfer_sync(src.segment_id, 0, dst.segment_id, 0, 8 << 20).ok
        assert eng.completions_drained == eng.slices_issued
        assert 1 <= eng.completion_batches <= eng.completions_drained


# ---------------------------------------------------------------------------
# Dispatch dirty-path regression (scalar substitution failure mid-wave with
# the failed batch's remaining slices spanning later runs)
# ---------------------------------------------------------------------------


class TestDirtyWaveCandidatelessRun:
    def _engine(self, wave: bool, monkeypatch):
        from repro.core import TentError
        from repro.core.types import Location, MemoryKind

        eng = TentEngine(
            FabricSpec(),
            config=EngineConfig(max_inflight=4096, wave=wave,
                                candidate_cache=wave),
            seed=0)
        # A: one intra-node host slice -> scalar run at the head of the wave.
        a_src = eng.register_segment(_host(0), 4096, materialize=False)
        a_dst = eng.register_segment(
            Location(node=0, kind=MemoryKind.HOST_DRAM, device=1, numa=1),
            4096, materialize=False)
        # B: cross-node elephant in the SAME batch, grouped behind A. Its
        # best route's stage gets an empty candidate set, so the run head
        # hits the `not sc.paths` fallback.
        b_src = eng.register_segment(_host(0), 8 << 20, materialize=False)
        b_dst = eng.register_segment(_host(1), 8 << 20, materialize=False)

        real_choose = eng.policy.choose
        monkeypatch.setattr(
            eng.policy, "choose",
            lambda cands, length: (_ for _ in ()).throw(
                TentError("NoEligibleDevice", "forced")) if length == 4096
            else real_choose(cands, length))
        # A (intra-node) cannot substitute -> its failure kills the batch;
        # B (cross-node) still has real fallback transports available
        from repro.core import TransportPlan
        real_sub = TransportPlan.substitute
        monkeypatch.setattr(
            TransportPlan, "substitute",
            lambda self: False if self.src.node == self.dst.node
            else real_sub(self))
        # empty B's rdma candidate set at *dispatch* time only (patching the
        # backend's `paths` would also zero `rank_bandwidth` and delete the
        # route at plan time, never reaching the `not sc.paths` branch)
        from repro.core import engine as engine_mod
        real_build = engine_mod.build_stage_candidates
        monkeypatch.setattr(
            engine_mod, "build_stage_candidates",
            lambda stage, backends, store, **kw: (
                lambda sc: dataclasses.replace(
                    sc, paths=[], cands=[], path_by_link={})
                if stage.backend == "rdma" else sc
            )(real_build(stage, backends, store, **kw)))
        return eng, (a_src, a_dst, b_src, b_dst)

    @pytest.mark.parametrize("wave", [True, False])
    def test_dead_batch_slices_never_reach_substitution(self, wave, monkeypatch):
        """Once a scalar substitution failure kills the batch mid-wave, a
        later run whose stage has no candidates must DROP the dead batch's
        slices — not hand them to the substitution path, which would post
        them on the next-best transport for an already-failed batch. The
        wave dispatcher must match the one-slice loop exactly."""
        eng, (a_src, a_dst, b_src, b_dst) = self._engine(wave, monkeypatch)
        b = eng.allocate_batch()
        eng.submit_transfer(b, [
            (a_src.segment_id, 0, a_dst.segment_id, 0, 4096),
            (b_src.segment_id, 0, b_dst.segment_id, 0, 8 << 20),
        ])
        state, _ = eng.get_transfer_status(b)
        assert state.value == "failed"
        eng.run_until_idle()
        assert eng.slices_issued == 0, \
            "dead batch slices were posted via backend substitution"
        assert eng.backend_substitutions == 0
        assert all(tl.queued_bytes == 0 for _, tl in eng.store.items())
        assert eng.fabric.bytes_by_tenant() == {}
