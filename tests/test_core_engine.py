"""End-to-end behaviour tests for the TENT engine on the simulated fabric."""
import numpy as np
import pytest

from repro.core import (
    BatchState,
    EngineConfig,
    FabricSpec,
    Location,
    MemoryKind,
    TentEngine,
)


def _rand(n, seed=0):
    return np.random.default_rng(seed).integers(0, 256, size=n, dtype=np.uint8)


def host_loc(node, numa=0):
    return Location(node=node, kind=MemoryKind.HOST_DRAM, device=numa, numa=numa)


def gpu_loc(node, gpu, spec=None):
    numa = (spec or FabricSpec()).node.gpu_numa(gpu)
    return Location(node=node, kind=MemoryKind.DEVICE_HBM, device=gpu, numa=numa)


class TestDataIntegrity:
    def test_host_to_host_cross_node(self):
        eng = TentEngine(FabricSpec())
        n = 8 * 1024 * 1024
        payload = _rand(n)
        src = eng.register_segment(host_loc(0), n)
        dst = eng.register_segment(host_loc(1), n)
        src.write(0, payload)
        res = eng.transfer_sync(src.segment_id, 0, dst.segment_id, 0, n)
        assert res.ok
        np.testing.assert_array_equal(dst.read(0, n), payload)

    def test_partial_offsets(self):
        eng = TentEngine(FabricSpec())
        src = eng.register_segment(host_loc(0), 1 << 20)
        dst = eng.register_segment(host_loc(1), 1 << 20)
        payload = _rand(100_000, seed=3)
        src.write(7777, payload)
        res = eng.transfer_sync(src.segment_id, 7777, dst.segment_id, 31337, 100_000)
        assert res.ok
        np.testing.assert_array_equal(dst.read(31337, 100_000), payload)

    def test_gpu_to_gpu_intra_node_uses_nvlink(self):
        eng = TentEngine(FabricSpec())
        n = 32 * 1024 * 1024
        src = eng.register_segment(gpu_loc(0, 0), n)
        dst = eng.register_segment(gpu_loc(0, 5), n)
        payload = _rand(n, seed=1)
        src.write(0, payload)
        res = eng.transfer_sync(src.segment_id, 0, dst.segment_id, 0, n)
        assert res.ok
        np.testing.assert_array_equal(dst.read(0, n), payload)
        nvlink = eng.topology.nvlink(0, 0)
        assert eng.fabric.link(nvlink.link_id).bytes_completed >= n

    def test_staged_route_without_gpudirect(self):
        spec = FabricSpec(has_gpudirect=False, has_nvlink=True)
        eng = TentEngine(spec)
        n = 4 * 1024 * 1024
        src = eng.register_segment(gpu_loc(0, 0, spec), n)
        dst = eng.register_segment(gpu_loc(1, 0, spec), n)
        plan = eng.orchestrator.resolve(src, dst)
        assert len(plan.current.stages) == 3  # D2H -> H2H -> H2D
        assert plan.current.backend_names == ["pcie", "rdma", "pcie"]
        payload = _rand(n, seed=2)
        src.write(0, payload)
        res = eng.transfer_sync(src.segment_id, 0, dst.segment_id, 0, n)
        assert res.ok
        np.testing.assert_array_equal(dst.read(0, n), payload)

    def test_file_to_gpu(self):
        eng = TentEngine(FabricSpec())
        n = 1 << 20
        src = eng.register_segment(Location(node=0, kind=MemoryKind.FILE), n)
        dst = eng.register_segment(gpu_loc(0, 1), n)
        payload = _rand(n, seed=9)
        src.write(0, payload)
        res = eng.transfer_sync(src.segment_id, 0, dst.segment_id, 0, n)
        assert res.ok
        np.testing.assert_array_equal(dst.read(0, n), payload)


class TestSpraying:
    def test_host_elephant_flow_uses_multiple_rails(self):
        eng = TentEngine(FabricSpec())
        n = 256 * 1024 * 1024
        src = eng.register_segment(host_loc(0), n)
        dst = eng.register_segment(host_loc(1), n)
        res = eng.transfer_sync(src.segment_id, 0, dst.segment_id, 0, n)
        assert res.ok
        used = [
            l.desc.name
            for l in eng.fabric.links.values()
            if l.bytes_completed > 0 and l.desc.link_class.value == "rdma" and l.desc.node == 0
        ]
        assert len(used) >= 4, f"expected multi-rail spray, got {used}"

    def test_gpu_large_block_recruits_tier2(self):
        # Paper §5.1.3: tier-1 NIC dominates small blocks; large blocks
        # spill over onto same-NUMA tier-2 NICs.
        spec = FabricSpec()
        eng = TentEngine(spec)
        n = 512 * 1024 * 1024
        src = eng.register_segment(gpu_loc(0, 0, spec), n)
        dst = eng.register_segment(gpu_loc(1, 0, spec), n)
        res = eng.transfer_sync(src.segment_id, 0, dst.segment_id, 0, n)
        assert res.ok
        tier1 = eng.topology.rdma_nic(0, spec.node.tier1_nic(0))
        t1_bytes = eng.fabric.link(tier1.link_id).bytes_completed
        rdma_total = sum(
            l.bytes_completed
            for l in eng.fabric.links.values()
            if l.desc.link_class.value == "rdma" and l.desc.node == 0
        )
        assert rdma_total >= n
        assert 0 < t1_bytes < rdma_total  # tier-2 rails recruited
        # tier-3 (cross-NUMA from GPU0) rails must stay cold (penalty inf)
        for nic in eng.topology.rdma_nics(0):
            if eng.topology.nic_tier(src.location, nic) == 3:
                assert eng.fabric.link(nic.link_id).bytes_completed == 0


class TestResilience:
    def test_failure_midtransfer_recovers(self):
        spec = FabricSpec()
        eng = TentEngine(spec)
        n = 128 * 1024 * 1024
        src = eng.register_segment(host_loc(0), n)
        dst = eng.register_segment(host_loc(1), n)
        payload = _rand(n, seed=4)
        src.write(0, payload)
        # Fail one NIC shortly after the transfer starts, recover later.
        nic = eng.topology.rdma_nic(0, 0)
        eng.fabric.schedule_failure(nic.link_id, at=0.0002, recover_at=0.5)
        res = eng.transfer_sync(src.segment_id, 0, dst.segment_id, 0, n)
        assert res.ok, res.error
        np.testing.assert_array_equal(dst.read(0, n), payload)
        assert eng.slices_retried > 0

    def test_all_rdma_down_substitutes_tcp(self):
        spec = FabricSpec()
        eng = TentEngine(spec)
        n = 2 * 1024 * 1024
        src = eng.register_segment(host_loc(0), n)
        dst = eng.register_segment(host_loc(1), n)
        payload = _rand(n, seed=5)
        src.write(0, payload)
        for nic in eng.topology.rdma_nics(0):
            eng.fabric.schedule_failure(nic.link_id, at=0.0, recover_at=1e9)
        for nic in eng.topology.rdma_nics(1):
            eng.fabric.schedule_failure(nic.link_id, at=0.0, recover_at=1e9)
        res = eng.transfer_sync(src.segment_id, 0, dst.segment_id, 0, n)
        assert res.ok, res.error
        assert eng.backend_substitutions > 0
        np.testing.assert_array_equal(dst.read(0, n), payload)
        tcp = eng.topology.tcp(0)
        assert eng.fabric.link(tcp.link_id).bytes_completed >= n


class TestPolicyComparison:
    def test_tent_beats_round_robin_on_degraded_fabric(self):
        # Paper Fig. 2 / §2.2: a degraded rail drags RR's whole transfer;
        # TENT steers slices away from it.
        results = {}
        for policy in ("tent", "round_robin"):
            eng = TentEngine(FabricSpec(), config=EngineConfig(policy=policy), seed=11)
            n = 256 * 1024 * 1024
            src = eng.register_segment(host_loc(0), n)
            dst = eng.register_segment(host_loc(1), n)
            nic = eng.topology.rdma_nic(0, 1)
            eng.fabric.schedule_degradation(nic.link_id, at=0.0, until=1e9, factor=0.12)
            res = eng.transfer_sync(src.segment_id, 0, dst.segment_id, 0, n)
            assert res.ok
            results[policy] = res.throughput
        assert results["tent"] > 1.15 * results["round_robin"], results


class TestBatchSemantics:
    def test_multi_transfer_batch_single_completion(self):
        eng = TentEngine(FabricSpec())
        n = 1 << 20
        segs = []
        for i in range(4):
            s = eng.register_segment(host_loc(0), n)
            d = eng.register_segment(host_loc(1), n)
            s.write(0, _rand(n, seed=i))
            segs.append((s, d))
        b = eng.allocate_batch()
        eng.submit_transfer(b, [(s.segment_id, 0, d.segment_id, 0, n) for s, d in segs])
        state, remaining = eng.get_transfer_status(b)
        assert state == BatchState.SUBMITTED and remaining > 0
        res = eng.wait(b)
        assert res.ok and res.bytes == 4 * n
        for s, d in segs:
            np.testing.assert_array_equal(d.read(0, n), s.read(0, n))
