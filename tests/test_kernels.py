"""Per-kernel correctness: shape/dtype sweeps, assert_allclose vs the
pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.kv_pack import kv_pack, kv_pack_ref, kv_unpack, kv_unpack_ref
from repro.kernels.ssd_scan import ssd_chunked as ssd_kernel
from repro.kernels.ssd_scan import ssd_scan_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-3, atol=2e-3)


# every (shape, dtype) combo compiles its own interpret-mode kernel, so the
# bf16 twins of each shape ride in the slow tier (same shapes, same oracle)
_BF16_SLOW = pytest.param(jnp.bfloat16, marks=pytest.mark.slow)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, _BF16_SLOW])
    @pytest.mark.parametrize(
        "B,S,H,K,D",
        [
            (1, 128, 4, 4, 64),  # MHA
            (2, 256, 8, 2, 64),  # GQA 4:1
            (1, 128, 4, 1, 128),  # MQA, wide head
            (1, 200, 4, 2, 64),  # non-block-multiple seq (padding path)
        ],
    )
    def test_causal_matches_ref(self, dtype, B, S, H, K, D):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, D), dtype)
        k = jax.random.normal(ks[1], (B, S, K, D), dtype)
        v = jax.random.normal(ks[2], (B, S, K, D), dtype)
        out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
        ref = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
        )

    @pytest.mark.parametrize("window", [16, 64])
    def test_sliding_window_matches_ref(self, window):
        B, S, H, K, D = 1, 256, 4, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
        out = flash_attention(q, k, v, causal=True, window=window, block_q=64, block_k=64)
        ref = flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)

    def test_matches_model_attention(self):
        """The kernel must agree with the model's attend_full path."""
        from repro.models.attention import attend_full

        B, S, H, K, D = 2, 128, 8, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, K, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, K, D), jnp.float32)
        out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
        ref = attend_full(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


class TestSSDScanKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, _BF16_SLOW])
    @pytest.mark.parametrize(
        "B,S,H,P,N,chunk",
        [
            (1, 128, 2, 16, 32, 32),
            (2, 256, 4, 64, 128, 64),
            (1, 100, 2, 16, 32, 32),  # padding path
        ],
    )
    def test_matches_recurrent_ref(self, dtype, B, S, H, P, N, chunk):
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        x = (jax.random.normal(ks[0], (B, S, H, P), jnp.float32) * 0.5).astype(dtype)
        a = (-jnp.abs(jax.random.normal(ks[1], (B, S, H), jnp.float32)) * 0.3).astype(dtype)
        Bm = (jax.random.normal(ks[2], (B, S, N), jnp.float32) * 0.5).astype(dtype)
        Cm = (jax.random.normal(ks[3], (B, S, N), jnp.float32) * 0.5).astype(dtype)
        y, fin = ssd_kernel(x, a, Bm, Cm, chunk=chunk)
        y_ref, fin_ref = ssd_scan_ref(x, a, Bm, Cm)
        np.testing.assert_allclose(
            np.asarray(y, np.float32), np.asarray(y_ref, np.float32), **_tol(dtype)
        )
        np.testing.assert_allclose(
            np.asarray(fin, np.float32), np.asarray(fin_ref, np.float32), **_tol(dtype)
        )

    def test_matches_model_ssd(self):
        """Kernel vs the model's chunked jnp implementation."""
        from repro.models.ssm import ssd_chunked as ssd_jnp

        B, S, H, P, N = 1, 128, 2, 32, 64
        ks = jax.random.split(jax.random.PRNGKey(1), 4)
        x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32) * 0.5
        a = -jnp.abs(jax.random.normal(ks[1], (B, S, H), jnp.float32)) * 0.3
        Bm = jax.random.normal(ks[2], (B, S, N), jnp.float32) * 0.5
        Cm = jax.random.normal(ks[3], (B, S, N), jnp.float32) * 0.5
        y_k, fin_k = ssd_kernel(x, a, Bm, Cm, chunk=32)
        y_j, fin_j = ssd_jnp(x, a, Bm, Cm, chunk=32)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_j), rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(
            np.asarray(fin_k, np.float32), np.asarray(fin_j, np.float32), rtol=2e-3, atol=2e-3
        )

    def test_initial_state(self):
        B, S, H, P, N = 1, 64, 2, 16, 32
        ks = jax.random.split(jax.random.PRNGKey(2), 5)
        x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32) * 0.5
        a = -jnp.abs(jax.random.normal(ks[1], (B, S, H), jnp.float32)) * 0.3
        Bm = jax.random.normal(ks[2], (B, S, N), jnp.float32) * 0.5
        Cm = jax.random.normal(ks[3], (B, S, N), jnp.float32) * 0.5
        s0 = jax.random.normal(ks[4], (B, H, P, N), jnp.float32)
        y, fin = ssd_kernel(x, a, Bm, Cm, chunk=32, initial_state=s0)
        y_ref, fin_ref = ssd_scan_ref(x, a, Bm, Cm, initial_state=s0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(fin), np.asarray(fin_ref), rtol=2e-3, atol=2e-3)


class TestKvPack:
    @pytest.mark.parametrize("dtype", [_BF16_SLOW, jnp.float32])
    @pytest.mark.parametrize("pages,page,dim,n", [(32, 16, 128, 8), (64, 8, 256, 64)])
    def test_pack_matches_ref(self, dtype, pages, page, dim, n):
        pool = jax.random.normal(jax.random.PRNGKey(0), (pages, page, dim), dtype)
        idx = jax.random.permutation(jax.random.PRNGKey(1), pages)[:n].astype(jnp.int32)
        out = kv_pack(pool, idx)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(kv_pack_ref(pool, idx)))

    def test_unpack_matches_ref(self):
        pool = jax.random.normal(jax.random.PRNGKey(0), (32, 16, 128), jnp.float32)
        buf = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 128), jnp.float32)
        idx = jax.random.permutation(jax.random.PRNGKey(2), 32)[:8].astype(jnp.int32)
        ref = kv_unpack_ref(pool, buf, idx)
        out = kv_unpack(pool.copy(), buf, idx)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_roundtrip(self):
        pool = jax.random.normal(jax.random.PRNGKey(3), (16, 8, 128), jnp.bfloat16)
        idx = jnp.asarray([3, 7, 1, 9], jnp.int32)
        buf = kv_pack(pool, idx)
        restored = kv_unpack(jnp.zeros_like(pool), buf, idx)
        np.testing.assert_array_equal(np.asarray(restored[idx]), np.asarray(pool[idx]))
