"""Per-architecture smoke tests: a reduced variant of each assigned family
runs one forward + one train step and one decode step on CPU, asserting
output shapes and absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import decode_step, forward, init_cache, init_params, loss_fn


def _batch(cfg, B=2, S=16, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    b = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.is_encdec:
        b["enc_frames"] = jax.random.normal(ks[2], (B, 8, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_full_config_dims(self, arch):
        cfg = get_config(arch)
        assert cfg.source, f"{arch} must cite its source"
        assert cfg.param_count() > 0

    @pytest.mark.slow
    def test_forward_and_train_step(self, arch):
        cfg = get_smoke_config(arch)
        assert cfg.num_layers <= 2 and cfg.d_model <= 512 and cfg.num_experts <= 4
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)
        logits, aux = forward(cfg, params, batch["tokens"], enc_frames=batch.get("enc_frames"))
        assert logits.shape == (2, 16, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any()), "NaN in logits"

        # one SGD-flavored train step: grads flow through every leaf family
        def loss(p):
            return loss_fn(cfg, p, batch)[0]

        l0, grads = jax.value_and_grad(loss)(params)
        assert np.isfinite(float(l0))
        flat = jax.tree_util.tree_leaves(grads)
        assert all(not bool(jnp.isnan(g).any()) for g in flat), "NaN grads"
        new_params = jax.tree_util.tree_map(lambda p, g: p - 0.01 * g.astype(p.dtype), params, grads)
        l1 = loss(new_params)
        assert np.isfinite(float(l1))

    @pytest.mark.slow
    def test_decode_step(self, arch):
        cfg = get_smoke_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        B = 2
        enc_len = 8 if cfg.is_encdec else 0
        cache = init_cache(cfg, B, max_len=32, enc_len=enc_len)
        token = jnp.zeros((B, 1), jnp.int32)
        logits, cache2 = decode_step(cfg, params, cache, token, jnp.int32(0))
        assert logits.shape == (B, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any())
        # cache structure preserved
        assert set(cache2.keys()) == set(cache.keys())
        for k in cache:
            assert cache2[k].shape == cache[k].shape, k
