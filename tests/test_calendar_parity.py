"""Calendar-queue fabric event loop: bit-parity + ordering invariants.

PR 9 puts a bucketed timestamp wheel (`repro.core.calqueue.CalendarQueue`)
under the fabric's event loop as an O(1)-amortized alternative to the binary
heap, toggled by `FabricConfig(event_queue="calendar")` and plumbed through
`EngineParams.calendar_queue` — the same pure-cost-change discipline as
wave/wave_complete/jit_core before it. These tests pin:

  * byte-identical `ScenarioReport`s across the toggle for the full scenario
    library (the spec echo of the toggle itself is the only permitted
    difference);
  * the wheel's ordering contract against heapq on seeded randomized
    streams — monotonic-time pushes interleaved with pops, heavy timestamp
    ties (ties drain in post/seq order), far-future sentinels, and adaptive
    resize; the hypothesis twin lives in tests/test_properties.py.
"""
import dataclasses
import heapq
import json

import numpy as np
import pytest

from repro.core import CalendarQueue, Fabric, FabricConfig, FabricSpec, Topology
from repro.core.fabric import FAR_WINDOW
from repro.scenarios import SCENARIOS, ScenarioRunner, get

# the one production-scale scenario is shrunk for the double-run parity
# sweep: the toggle's bit-parity is about event *order*, which does not
# depend on stream size, and CI should pay seconds here, not minutes
_SHRINK = {"serving_production_stream": 5_000}


def _normalized_report(spec) -> str:
    d = ScenarioRunner(spec).run().to_dict()
    # the toggle's own spec echo is the single permitted difference
    d["spec"]["engine"]["calendar_queue"] = None
    return json.dumps(d, sort_keys=True)


def _with_calendar(spec, on=True):
    return dataclasses.replace(
        spec, engine=dataclasses.replace(spec.engine, calendar_queue=on))


def _sized(spec):
    n = _SHRINK.get(spec.name)
    if n is not None:
        spec = dataclasses.replace(
            spec, workload=dataclasses.replace(spec.workload, stream_requests=n))
    return spec


class TestCalendarFabricBitIdentity:
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_reports_identical_across_queue_toggle(self, name):
        """Heap vs calendar over the full scenario library: same pops in the
        same order => same virtual timeline => every report metric matches
        exactly, faults, turbulence, churn, and serving streams included."""
        spec = _sized(get(name))
        assert _normalized_report(_with_calendar(spec)) == \
            _normalized_report(spec)

    def test_fabric_callback_order_matches_heap(self):
        """Direct fabric-level pin: interleaved call_at/call_after with
        heavy timestamp ties must fire in identical order on both queues."""
        topo = Topology(FabricSpec(n_nodes=2))
        orders = {}
        for cfg in (FabricConfig(), FabricConfig(event_queue="calendar")):
            fab = Fabric(topo, seed=7, config=cfg)
            fired = []
            times = [0.003, 0.001, 0.002, 0.001, 0.001, 0.0025, 0.002]
            for i, t in enumerate(times):
                fab.call_at(t, lambda i=i: fired.append(i))
            fab.call_after(0.001, lambda: fired.append("after"))
            # a callback scheduling more work mid-drain, landing on a tie
            fab.call_at(0.002, lambda: fab.call_at(
                0.0025, lambda: fired.append("nested")))
            fab.run_until(0.01)
            orders[cfg.event_queue] = fired
        assert orders["calendar"] == orders["heap"]
        assert len(orders["heap"]) == 9


class TestCalendarQueueOrdering:
    """The wheel against heapq: exact (time, seq) pop order."""

    def _entries(self, rng, n, *, tie_frac=0.0, far_frac=0.0, span=1.0):
        times = rng.uniform(0.0, span, size=n)
        if tie_frac:
            # collapse a fraction onto a handful of shared timestamps
            ties = rng.random(n) < tie_frac
            pool = rng.uniform(0.0, span, size=max(1, n // 16))
            times[ties] = rng.choice(pool, size=int(ties.sum()))
        if far_frac:
            far = rng.random(n) < far_frac
            times[far] = FAR_WINDOW
        return [(float(t), i, f"item{i}") for i, t in enumerate(times)]

    @pytest.mark.parametrize("width,threshold", [
        (1e-3, 4096), (1e-6, 8), (1.0, 64)])
    def test_bulk_drain_matches_heapq_seeded(self, width, threshold):
        rng = np.random.default_rng(101)
        for trial in range(40):
            n = int(rng.integers(1, 400))
            entries = self._entries(
                rng, n, tie_frac=float(rng.choice([0.0, 0.5, 0.95])),
                far_frac=float(rng.choice([0.0, 0.1])),
                span=float(rng.choice([1e-4, 1.0, 1e4])))
            cal = CalendarQueue(width)
            cal.resize_threshold = threshold
            heap = []
            for e in entries:
                cal.push(e)
                heapq.heappush(heap, e)
            got = [cal.pop() for _ in range(n)]
            want = [heapq.heappop(heap) for _ in range(n)]
            assert got == want, f"trial {trial}"
            assert len(cal) == 0

    def test_interleaved_monotonic_push_pop_matches_heapq(self):
        """The fabric's actual access pattern: the clock only moves forward,
        so new work is posted at times >= the last pop (plus jittered
        service ends slightly beyond it), interleaved with drains."""
        rng = np.random.default_rng(202)
        for trial in range(30):
            cal = CalendarQueue(1e-3)
            cal.resize_threshold = int(rng.choice([8, 64, 4096]))
            heap = []
            now, seq = 0.0, 0
            for _ in range(int(rng.integers(10, 60))):
                for _ in range(int(rng.integers(1, 12))):
                    t = now + float(rng.uniform(0.0, 5e-3))
                    e = (t, seq, seq)
                    seq += 1
                    cal.push(e)
                    heapq.heappush(heap, e)
                for _ in range(int(rng.integers(0, 10))):
                    if not heap:
                        break
                    want = heapq.heappop(heap)
                    got = cal.pop()
                    assert got == want, f"trial {trial}"
                    now = got[0]
            while heap:
                assert cal.pop() == heapq.heappop(heap)

    def test_ties_drain_in_post_order(self):
        """All entries at one timestamp: pops must come back in seq (post)
        order — the property the engine's same-timestamp completion
        batching and the serving stepper's cohort callbacks rely on."""
        cal = CalendarQueue(1e-3)
        order = list(range(500))
        rng = np.random.default_rng(7)
        rng.shuffle(order)
        for seq in order:
            cal.push((0.125, seq, f"p{seq}"))
        assert [cal.pop()[1] for _ in range(500)] == list(range(500))

    def test_push_behind_current_bucket_stays_ordered(self):
        """peek() advances the wheel to the earliest bucket; a later push
        landing at-or-before that bucket must join the *current* bucket's
        heap, not a stale dict bucket the wheel already passed."""
        cal = CalendarQueue(1e-3)
        cal.push((0.0105, 0, "a"))
        assert cal.peek() == (0.0105, 0, "a")  # wheel advanced to bucket 10
        cal.push((0.0101, 1, "b"))  # same bucket, earlier time
        cal.push((0.0052, 2, "c"))  # EARLIER bucket than current
        assert cal.pop() == (0.0052, 2, "c")
        assert cal.pop() == (0.0101, 1, "b")
        assert cal.pop() == (0.0105, 0, "a")

    def test_adaptive_resize_preserves_order_and_len(self):
        """One pathological bucket (every entry in a single width window)
        forces the width/4 rebuild; order and length must survive it."""
        cal = CalendarQueue(1.0)
        cal.resize_threshold = 32
        rng = np.random.default_rng(11)
        times = rng.uniform(0.25, 0.26, size=500)  # all in bucket 0
        entries = sorted((float(t), i, i) for i, t in enumerate(times))
        for e in sorted(entries, key=lambda e: e[1]):  # push in seq order
            cal.push(e)
        assert len(cal) == 500
        assert [cal.pop() for _ in range(500)] == entries
        # the rebuild fires lazily on the first drain of the fat bucket
        assert cal.width < 1.0

    def test_len_and_bool(self):
        cal = CalendarQueue(1e-3)
        assert not cal and len(cal) == 0
        cal.push((0.5, 0, None))
        assert cal and len(cal) == 1
        cal.pop()
        assert not cal

    def test_pop_empty_raises(self):
        with pytest.raises(IndexError):
            CalendarQueue(1e-3).pop()


class TestFabricConfig:
    def test_bad_queue_kind_rejected(self):
        with pytest.raises(ValueError):
            FabricConfig(event_queue="wheel-of-fortune")

    def test_negative_width_rejected(self):
        with pytest.raises(ValueError):
            FabricConfig(event_queue="calendar", calendar_width=-1.0)

    def test_default_is_heap(self):
        topo = Topology(FabricSpec())
        assert Fabric(topo, seed=0)._cal is None
        assert Fabric(topo, seed=0,
                      config=FabricConfig(event_queue="calendar"))._cal \
            is not None
