"""Bench trajectory diff driver: asymmetric-document robustness.

`benchmarks/diff.py` compares two BENCH_*.json trajectories that may come
from different revisions of the tooling — scenarios appear and disappear,
and report schemas drift. Asymmetries must be *reported*, never crash the
diff and never be silently skipped (a half-written candidate must not look
healthy to `--fail-on-regression`).
"""
import json

import pytest

from benchmarks.diff import SCHEMA, diff_reports, load_reports, main


def _report(name, throughput=1e9, ok=True, policy="tent", **overrides):
    rep = {
        "policy": policy,
        "ok": True,
        "throughput": throughput,
        "recovery_ms": -1.0,
        "stall_ms": -1.0,
        "extra": {},
    }
    rep.update(overrides)
    return {
        "scenario": name,
        "ok": ok,
        "violations": [],
        "policies": {policy: rep},
        "spec": {"policies": [policy]},
    }


def _doc(path, reports):
    path.write_text(json.dumps({
        "schema": SCHEMA,
        "generated_unix": 0.0,
        "scenarios": len(reports),
        "violated": 0,
        "reports": reports,
    }))
    return str(path)


class TestScenarioAsymmetry:
    def test_scenario_only_in_candidate_is_reported_as_added(self, tmp_path, capsys):
        old = _doc(tmp_path / "old.json", [_report("a")])
        new = _doc(tmp_path / "new.json", [_report("a"), _report("b")])
        main([old, new, "--fail-on-regression", "5"])  # must not crash/exit 1
        out = capsys.readouterr().out
        assert "+ b: only in the new trajectory" in out

    def test_scenario_only_in_baseline_is_reported_as_removed(self, tmp_path, capsys):
        old = _doc(tmp_path / "old.json", [_report("a"), _report("gone")])
        new = _doc(tmp_path / "new.json", [_report("a")])
        main([old, new, "--fail-on-regression", "5"])
        out = capsys.readouterr().out
        assert "- gone: only in the old trajectory" in out

    def test_disjoint_trajectories_still_render(self, tmp_path, capsys):
        old = _doc(tmp_path / "old.json", [_report("only_old")])
        new = _doc(tmp_path / "new.json", [_report("only_new")])
        main([old, new])
        out = capsys.readouterr().out
        assert "+ only_new" in out and "- only_old" in out


class TestMetricAsymmetry:
    def _throughputless(self, name):
        rep = _report(name)
        del rep["policies"]["tent"]["throughput"]
        return rep

    def test_metric_missing_in_baseline_reports_not_crashes(self, tmp_path, capsys):
        old = _doc(tmp_path / "old.json", [self._throughputless("a"), _report("b")])
        new = _doc(tmp_path / "new.json", [_report("a"), _report("b")])
        main([old, new])  # reporting mode: surfaced, not a crash
        err = capsys.readouterr().err
        assert "baseline is missing metric 'throughput'" in err
        assert "a [tent]" in err

    def test_metric_missing_in_candidate_reports_not_crashes(self, tmp_path, capsys):
        old = _doc(tmp_path / "old.json", [_report("a"), _report("b")])
        new = _doc(tmp_path / "new.json", [self._throughputless("a"), _report("b")])
        main([old, new])
        err = capsys.readouterr().err
        assert "candidate is missing metric 'throughput'" in err

    def test_incomparable_scenarios_fail_the_regression_gate(self, tmp_path, capsys):
        """A half-written candidate (metric missing) must not pass
        --fail-on-regression by being impossible to compare."""
        old = _doc(tmp_path / "old.json", [_report("a"), _report("b")])
        new = _doc(tmp_path / "new.json", [self._throughputless("a"), _report("b")])
        with pytest.raises(SystemExit, match="1"):
            main([old, new, "--fail-on-regression", "5"])
        assert "could not be compared" in capsys.readouterr().err

    def test_expectation_flip_waiver_still_gates_throughput(self, tmp_path, capsys):
        """--allow-expectation-regressions excuses ok->violated flips (noisy
        wall-clock floors) but never a real throughput drop."""
        old = _doc(tmp_path / "old.json", [_report("a", ok=True)])
        new = _doc(tmp_path / "new.json", [_report("a", ok=False)])
        main([old, new, "--fail-on-regression", "5",
              "--allow-expectation-regressions"])
        assert "warning: expectations regressed" in capsys.readouterr().err
        with pytest.raises(SystemExit, match="1"):
            main([old, new, "--fail-on-regression", "5"])
        dropped = _doc(tmp_path / "drop.json", [_report("a", throughput=1e8, ok=False)])
        with pytest.raises(SystemExit, match="1"):
            main([old, dropped, "--fail-on-regression", "5",
                  "--allow-expectation-regressions"])

    def test_missing_secondary_metrics_render_as_not_applicable(self, tmp_path, capsys):
        rep = _report("a")
        del rep["policies"]["tent"]["recovery_ms"]
        del rep["policies"]["tent"]["stall_ms"]
        old = _doc(tmp_path / "old.json", [rep])
        new = _doc(tmp_path / "new.json", [_report("a")])
        main([old, new])  # missing recovery/stall: still a comparable row
        out = capsys.readouterr().out
        assert "a" in out and "tent" in out

    def test_incomparable_rows_surface_in_diff_reports(self, tmp_path):
        old = load_reports(_doc(tmp_path / "old.json", [self._throughputless("a")]))
        new = load_reports(_doc(tmp_path / "new.json", [_report("a")]))
        rows, added, removed, skipped, incomparable = diff_reports(old, new)
        assert rows == [] and added == [] and removed == [] and skipped == []
        assert len(incomparable) == 1 and "a [tent]" in incomparable[0]


class TestDocumentShape:
    def test_document_without_reports_section_errors_cleanly(self, tmp_path):
        p = tmp_path / "bad.json"
        p.write_text(json.dumps({"schema": SCHEMA}))
        with pytest.raises(SystemExit, match="no 'reports' section"):
            load_reports(str(p))

    def test_report_without_scenario_name_errors_cleanly(self, tmp_path):
        p = tmp_path / "bad.json"
        p.write_text(json.dumps({
            "schema": SCHEMA, "reports": [{"policies": {}}]}))
        with pytest.raises(SystemExit, match="without a 'scenario' name"):
            load_reports(str(p))

    def test_regression_gate_still_fires_on_real_drop(self, tmp_path):
        old = _doc(tmp_path / "old.json", [_report("a", throughput=1e9)])
        new = _doc(tmp_path / "new.json", [_report("a", throughput=0.5e9)])
        with pytest.raises(SystemExit, match="1"):
            main([old, new, "--fail-on-regression", "5"])
