"""Monte-Carlo sweep regression tier (`repro.scenarios.sweep`).

The sweep is the statistical face of the fused lax.scan spray core
(`repro.core.jit_core`): a `ScenarioSpec` compiles once to a fixed-shape
`SprayProgram`, gets vmapped over N seeds with jittered fault windows, and
reports healing/throughput distributions. Everything here is pinned hard:
the whole `SweepReport` must be byte-identical across repeat runs (same
spec, same seed vector), every vmapped lane must equal the independently
jitted single-seed run bit for bit, the fused simulate must equal its
sequential numpy twin bit for bit, and declared distribution expectations
must surface as violations — the same determinism discipline the scalar
tiers (PRs 4-5) established, extended to the Monte-Carlo layer.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import jit_core
from repro.scenarios import MonteCarloSweep, get
from repro.scenarios.sweep import compile_spray_program, sweepable_names

pytestmark = pytest.mark.skipif(
    not jit_core.jax_available(), reason="the fused sweep core requires jax")

FLAP = "single_rail_flap"


@pytest.fixture(scope="module")
def flap_sweep_64():
    """One 64-seed sweep of the flap scenario, shared by the acceptance
    checks (distribution shape) and the determinism checks (repeat run)."""
    return MonteCarloSweep(get(FLAP), n_seeds=64, fault_jitter=0.25).run()


class TestSweepDeterminism:
    def test_repeat_run_is_byte_identical(self, flap_sweep_64):
        """Same spec + same base seed => the serialized SweepReport cannot
        differ in a single byte (seeds derive from fold_in(base, i), the
        bootstrap rng from base_seed — nothing reads wall clock or global
        rng state)."""
        again = MonteCarloSweep(get(FLAP), n_seeds=64, fault_jitter=0.25).run()
        assert again.to_json(sort_keys=True) == \
            flap_sweep_64.to_json(sort_keys=True)

    def test_vmapped_lanes_equal_single_seed_runs(self):
        """Every lane of the vmapped sweep must be bit-identical to the
        independently jitted single-seed run: vmap is a batching transform,
        not a numerics license."""
        sweep = MonteCarloSweep(get(FLAP), n_seeds=8, fault_jitter=0.25)
        rep = sweep.run()
        for policy, dist in rep.policies.items():
            for i in range(8):
                thr, heal_s, bytes_ok, lost, mk = sweep.run_single(
                    i, policy=policy)
                assert dist.throughput[i] == thr, (policy, i)
                assert dist.makespan[i] == mk, (policy, i)
                assert dist.bytes_ok[i] == bytes_ok, (policy, i)
                assert dist.lost[i] == lost, (policy, i)
                want_ms = -1.0 if heal_s < 0 else min(heal_s * 1e3,
                                                      1e9)
                assert dist.healing_ms[i] == want_ms, (policy, i)

    @pytest.mark.parametrize("fault_jitter", [0.0, 0.25])
    def test_fused_sim_equals_numpy_twin(self, fault_jitter):
        """The jitted lax.scan simulate vs the sequential numpy reference,
        identical raw draws: every output bit-equal (the fused core keeps
        the same IEEE op order; FMA contraction is fenced off)."""
        spec = get(FLAP)
        p = compile_spray_program(spec)
        for policy in ("tent", "round_robin"):
            for seed in range(3):
                draws = jit_core.make_draws(
                    p, base_seed=spec.seed, seed_index=seed)
                ref = jit_core.simulate_spray_ref(
                    p, draws, policy=policy, fault_jitter=fault_jitter)
                got = jit_core.spray_single(
                    p, base_seed=spec.seed, seed_index=seed, policy=policy,
                    fault_jitter=fault_jitter)
                assert tuple(ref) == tuple(got), (policy, seed)


class TestSweepDistributions:
    def test_flap_healing_tail_is_sub_50ms_over_64_seeds(self, flap_sweep_64):
        """The paper's resilience claim at distribution level: across 64
        jittered flap realizations, tent's virtual healing P99.9 stays
        under the scenario's 50 ms ceiling and no seed leaves the fault
        unhealed."""
        tent = flap_sweep_64.policies["tent"]
        assert flap_sweep_64.n_seeds == 64
        p999 = tent.summary["healing_p999_ms"]
        assert 0.0 < p999 < 50.0
        heal = np.asarray(tent.healing_ms)
        assert (heal >= 0.0).all()  # every seed saw and healed the fault
        assert (heal < 1e9).all()  # none hit the never-healed cap
        lo, hi = (tent.summary["healing_p999_ci_lo"],
                  tent.summary["healing_p999_ci_hi"])
        assert lo <= p999 <= hi

    def test_declared_expectations_pass_on_the_flap(self, flap_sweep_64):
        """single_rail_flap declares MC expectations in the library
        (healing_p999_ms, throughput_p50_vs_baseline); the measured
        distributions must satisfy them."""
        assert flap_sweep_64.ok, flap_sweep_64.violations

    def test_violated_expectations_surface(self):
        """An impossible healing ceiling must produce a violation (and flip
        ok), not be silently clamped."""
        spec = get(FLAP)
        strict = dataclasses.replace(
            spec, expectations=dataclasses.replace(
                spec.expectations, healing_p999_ms=1e-6))
        rep = MonteCarloSweep(strict, n_seeds=8, fault_jitter=0.25).run()
        assert not rep.ok
        assert any("healing P99.9" in v for v in rep.violations)

    def test_throughput_floor_violation_surfaces(self):
        spec = get(FLAP)
        greedy = dataclasses.replace(
            spec, expectations=dataclasses.replace(
                spec.expectations, throughput_p50_vs_baseline=100.0))
        rep = MonteCarloSweep(greedy, n_seeds=8, fault_jitter=0.25).run()
        assert not rep.ok
        assert any("throughput P50" in v for v in rep.violations)


class TestSweepProjection:
    def test_scenario_report_projection_feeds_the_diff_gate(self,
                                                            flap_sweep_64):
        """`to_scenario_report` must emit the fields `benchmarks.diff`
        keys on: scenario name, primary-policy throughput, recovery/stall
        ms, ok, and the spec's policy order."""
        rep = flap_sweep_64.to_scenario_report()
        doc = rep.to_dict()
        assert doc["scenario"] == f"{FLAP}::mc"
        assert list(doc["policies"]) == list(get(FLAP).policies)
        tent = doc["policies"]["tent"]
        assert tent["throughput"] == \
            flap_sweep_64.policies["tent"].summary["throughput_p50"]
        assert tent["recovery_ms"] == \
            flap_sweep_64.policies["tent"].summary["healing_p50_ms"]
        assert tent["stall_ms"] == \
            flap_sweep_64.policies["tent"].summary["healing_p999_ms"]
        assert doc["spec"]["mc"]["n_seeds"] == 64

    def test_sweepable_names_excludes_non_closed_loop(self):
        names = sweepable_names()
        assert FLAP in names and "flap_storm" in names
        for name in names:
            compile_spray_program(get(name))  # every listed name compiles


class TestCompileRejections:
    def test_non_closed_loop_rejected(self):
        from repro.scenarios import SCENARIOS

        non_cl = [n for n in SCENARIOS if n not in sweepable_names()]
        assert non_cl, "library should contain non-sweepable scenarios"
        with pytest.raises(ValueError, match="closed-loop"):
            compile_spray_program(get(non_cl[0]))
