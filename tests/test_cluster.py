"""Cluster control plane: the omega blend path of the predictive model,
the global load diffusion table, failure-rumor propagation, the lossy/
delayed gossip channel with partial membership views and anti-entropy,
engine join/leave churn, and the multi-engine scenario acceptance claims
(diffusion-ON tent strictly beating diffusion-OFF tent under cross-engine
incast — with and without loss, partial views, and churn — cluster-wide
sub-50 ms virtual healing, zero lost slices on every engine)."""
import dataclasses

import pytest

from repro.cluster import (
    ClusterParams,
    EngineRole,
    GossipChannel,
    PeerSampler,
    TentCluster,
)
from repro.core import (
    Candidate,
    EngineConfig,
    FabricSpec,
    TelemetryStore,
    TentEngine,
    TentPolicy,
    Topology,
)
from repro.scenarios import (
    ScenarioRunner,
    engine_join,
    engine_leave,
    get,
    host_loc,
    run_cluster_workload,
)

# all gossip messages dropped, deterministically (loss must stay < 1.0)
NEAR_TOTAL_LOSS = 1.0 - 1e-12


def _store_with_links(n=4):
    store = TelemetryStore()
    topo = Topology(FabricSpec())
    tls = [store.ensure(l) for l in topo.links[:n]]
    return store, tls


# ---------------------------------------------------------------------------
# Omega blend (global_diffusion_weight > 0) — previously dormant, untested
# ---------------------------------------------------------------------------


class TestOmegaBlend:
    def test_effective_queue_adds_discounted_global_load(self):
        store, (tl, *_) = _store_with_links(1)
        tl.queued_bytes = 100
        assert store.effective_queue(tl) == 100.0  # omega off: local only
        store.global_weight = 0.5
        store.global_load[tl.desc.link_id] = 200
        assert store.effective_queue(tl) == 100 + 0.5 * 200

    def test_remote_pressure_gated_by_omega(self):
        store, (tl, *_) = _store_with_links(1)
        store.global_load[tl.desc.link_id] = 1000
        assert store.remote_pressure(tl.desc.link_id) == 0.0  # omega off
        store.global_weight = 0.6
        assert store.remote_pressure(tl.desc.link_id) == pytest.approx(600.0)

    def test_scores_penalize_globally_loaded_local_link(self):
        store, (a, b) = _store_with_links(2)
        store.global_weight = 0.6
        store.global_load[a.desc.link_id] = 64 << 20
        pol = TentPolicy(store=store)
        sa, sb = pol.scores([Candidate(a, 1), Candidate(b, 1)], 64 << 10)
        assert sa > sb

    def test_scores_penalize_remotely_loaded_path(self):
        store, (a, b, ra, rb) = _store_with_links(4)
        store.global_weight = 0.6
        store.global_load[ra.desc.link_id] = 64 << 20  # peers hammer a's remote
        pol = TentPolicy(store=store)
        sa, sb = pol.scores(
            [Candidate(a, 1, remote=ra), Candidate(b, 1, remote=rb)], 64 << 10)
        assert sa > sb

    def test_placement_shifts_away_from_remotely_loaded_links(self):
        """An engine with omega > 0 must steer slices off local rails whose
        *remote* endpoints the global table reports as loaded — the receiver
        side of an incast its own telemetry cannot see."""
        def run(omega):
            engine = TentEngine(
                FabricSpec(), config=EngineConfig(global_diffusion_weight=omega))
            if omega > 0:
                for nic in engine.topology.rdma_nics(1)[:4]:  # remote NICs 0-3
                    engine.store.global_load[nic.link_id] = 1 << 30
            src = engine.register_segment(host_loc(0, 0), 8 << 20, materialize=False)
            dst = engine.register_segment(host_loc(1, 0), 8 << 20, materialize=False)
            engine.transfer_sync(src.segment_id, 0, dst.segment_id, 0, 8 << 20)
            by_link = engine.bytes_by_link()
            nics = engine.topology.rdma_nics(0)
            loaded = sum(by_link[n.link_id] for n in nics[:4])
            clean = sum(by_link[n.link_id] for n in nics[4:])
            return loaded, clean

        loaded_on, clean_on = run(omega=0.6)
        assert loaded_on == 0 and clean_on == 8 << 20
        loaded_off, _ = run(omega=0.0)
        assert loaded_off > 0  # same table ignored without omega

    def test_rumored_remote_exclusion_blocks_the_path(self):
        engine = TentEngine(FabricSpec())
        remote0 = engine.topology.rdma_nic(1, 0)
        engine.health.exclude(remote0.link_id)  # as a rumor would
        src = engine.register_segment(host_loc(0, 0), 4 << 20, materialize=False)
        dst = engine.register_segment(host_loc(1, 0), 4 << 20, materialize=False)
        res = engine.transfer_sync(src.segment_id, 0, dst.segment_id, 0, 4 << 20)
        assert res.ok
        local0 = engine.topology.rdma_nic(0, 0)
        assert engine.bytes_by_link()[local0.link_id] == 0

    def test_shared_table_never_double_counts_own_load(self):
        """publish_global shared-table mode: an engine's own published
        entries must not inflate its own scores, and republishing replaces
        (not accumulates) its contribution."""
        store, (tl, other) = _store_with_links(2)
        store.global_weight = 0.5
        tl.queued_bytes = 100
        store.publish_global()
        assert store.global_load[tl.desc.link_id] == 100
        assert store.effective_queue(tl) == 100.0  # own load counted once
        assert store.remote_pressure(tl.desc.link_id) == 0.0
        store.publish_global()
        store.publish_global()
        assert store.global_load[tl.desc.link_id] == 100  # no accumulation
        tl.queued_bytes = 40
        store.publish_global()
        assert store.global_load[tl.desc.link_id] == 40  # replaced

    def test_snapshot_merges_local_and_remote_charges(self):
        store, (a, b) = _store_with_links(2)
        a.queued_bytes = 100
        store.charge_remote(b.desc.link_id, 70)
        store.charge_remote(a.desc.link_id, 5)
        assert store.snapshot() == {a.desc.link_id: 105, b.desc.link_id: 70}
        store.discharge_remote(b.desc.link_id, 70)
        assert store.snapshot() == {a.desc.link_id: 105}


# ---------------------------------------------------------------------------
# TentCluster construction
# ---------------------------------------------------------------------------


class TestTentCluster:
    def test_disjoint_role_ownership_enforced(self):
        spec = FabricSpec(n_nodes=2)
        with pytest.raises(ValueError, match="owned by both"):
            TentCluster(spec, [EngineRole("a", (0,)), EngineRole("b", (0, 1))])
        with pytest.raises(ValueError, match="outside"):
            TentCluster(spec, [EngineRole("a", (5,))])
        with pytest.raises(ValueError, match="duplicate"):
            TentCluster(spec, [EngineRole("a", (0,)), EngineRole("a", (1,))])
        with pytest.raises(ValueError, match="owns no nodes"):
            EngineRole("a", ())

    def test_engines_share_fabric_and_clock(self):
        cluster = TentCluster(
            FabricSpec(n_nodes=2), [EngineRole("a", (0,)), EngineRole("b", (1,))])
        a, b = cluster.engines["a"], cluster.engines["b"]
        assert a.fabric is b.fabric is cluster.fabric
        assert cluster.engine_for_node(0) is a
        assert cluster.engine_for_node(1) is b

    def test_diffusion_switch_gates_omega_and_services(self):
        roles = [EngineRole("a", (0,)), EngineRole("b", (1,))]
        on = TentCluster(FabricSpec(n_nodes=2), roles,
                         params=ClusterParams(diffusion=True, global_weight=0.7))
        off = TentCluster(FabricSpec(n_nodes=2), roles,
                          params=ClusterParams(diffusion=False, global_weight=0.7))
        assert on.diffusion is not None and on.membership is not None
        assert off.diffusion is None and off.membership is None
        assert all(e.store.global_weight == 0.7 for e in on.engines.values())
        assert all(e.store.global_weight == 0.0 for e in off.engines.values())

    def test_per_role_policy_override(self):
        cluster = TentCluster(
            FabricSpec(n_nodes=2),
            [EngineRole("a", (0,)), EngineRole("c", (1,), policy="static_best2")])
        assert cluster.engines["a"].config.policy == "tent"
        assert cluster.engines["c"].config.policy == "static_best2"

    def test_cluster_transfer_and_tenant_accounting(self):
        cluster = TentCluster(
            FabricSpec(n_nodes=2), [EngineRole("a", (0,)), EngineRole("b", (1,))])
        for name, node in (("a", 0), ("b", 1)):
            e = cluster.engines[name]
            src = e.register_segment(host_loc(node, 0), 1 << 20, materialize=False)
            dst = e.register_segment(host_loc(1 - node, 0), 1 << 20, materialize=False)
            bid = e.allocate_batch()
            e.submit_transfer(bid, [(src.segment_id, 0, dst.segment_id, 0, 1 << 20)])
        cluster.run_until_idle()
        audit = cluster.audit()
        assert audit["total"]["batches_done"] == 2
        assert audit["total"]["slices_outstanding"] == 0
        tenants = cluster.fabric.bytes_by_tenant()
        assert tenants["a"] == 1 << 20 and tenants["b"] == 1 << 20


# ---------------------------------------------------------------------------
# GlobalLoadTable
# ---------------------------------------------------------------------------


def _two_engine_cluster(**params):
    return TentCluster(
        FabricSpec(n_nodes=2),
        [EngineRole("a", (0,)), EngineRole("b", (1,))],
        params=ClusterParams(**params),
    )


class TestGlobalLoadTable:
    def test_diffusion_excludes_own_footprint(self):
        cluster = _two_engine_cluster(diffusion=True)
        a, b = cluster.engines["a"], cluster.engines["b"]
        lid = cluster.topology.rdma_nic(0, 3).link_id
        a.store.get(lid).queued_bytes = 1234
        a.store.charge_remote(lid + 1, 55)
        table = cluster.diffusion
        table.publish()
        table.diffuse()
        assert b.store.global_load == {lid: 1234, lid + 1: 55}
        assert a.store.global_load == {}  # own entries never reflected back

    def test_stale_snapshots_are_dropped(self):
        cluster = _two_engine_cluster(diffusion=True, diffusion_staleness=0.01)
        a, b = cluster.engines["a"], cluster.engines["b"]
        lid = cluster.topology.rdma_nic(0, 0).link_id
        a.store.get(lid).queued_bytes = 999
        cluster.diffusion.publish()
        cluster.fabric.run_until(0.5)  # way past the staleness horizon
        cluster.diffusion.diffuse()
        assert b.store.global_load == {}

    def test_timer_quiesces_when_idle(self):
        cluster = _two_engine_cluster(diffusion=True, diffusion_period=0.001)
        cluster.start()
        cluster.run_until_idle()  # must terminate: no open work -> no re-arm
        assert cluster.diffusion.rounds == 1

    def test_timer_runs_while_work_is_open(self):
        cluster = _two_engine_cluster(diffusion=True, diffusion_period=0.0005)
        e = cluster.engines["a"]
        src = e.register_segment(host_loc(0, 0), 256 << 20, materialize=False)
        dst = e.register_segment(host_loc(1, 0), 256 << 20, materialize=False)
        bid = e.allocate_batch()
        e.submit_transfer(bid, [(src.segment_id, 0, dst.segment_id, 0, 256 << 20)])
        cluster.start()
        res = e.wait(bid)
        assert res.ok and cluster.diffusion.rounds >= 2


# ---------------------------------------------------------------------------
# Failure rumors
# ---------------------------------------------------------------------------


class TestFailureRumors:
    def test_explicit_path_failure_gossips_both_suspects(self):
        cluster = _two_engine_cluster(diffusion=True, gossip_delay=0.0005)
        a, b = cluster.engines["a"], cluster.engines["b"]
        local = cluster.topology.rdma_nic(0, 2).link_id
        remote = cluster.topology.rdma_nic(1, 2).link_id
        a.health.on_path_failure(local, remote)
        assert cluster.membership.rumors_sent == 2
        assert not b.store.get(local).excluded  # not before the gossip delay
        cluster.fabric.run_until(0.001)
        assert b.store.get(local).excluded and b.store.get(remote).excluded

    def test_implicit_exclusion_stays_local(self):
        """Slow-rail exclusions are congestion estimates; they travel through
        the load table, not the rumor mill (no cluster-wide herding)."""
        cluster = _two_engine_cluster(diffusion=True)
        a, b = cluster.engines["a"], cluster.engines["b"]
        lid = cluster.topology.rdma_nic(0, 1).link_id
        a.health.exclude(lid)  # implicit (no explicit wire error)
        cluster.fabric.run_until(0.01)
        assert cluster.membership.rumors_sent == 0
        assert a.store.get(lid).excluded and not b.store.get(lid).excluded

    def test_readmission_gossips_only_rumored_links(self):
        cluster = _two_engine_cluster(diffusion=True, gossip_delay=0.0005)
        a, b = cluster.engines["a"], cluster.engines["b"]
        rumored = cluster.topology.rdma_nic(0, 2).link_id
        private = cluster.topology.rdma_nic(0, 5).link_id
        a.health.on_explicit_failure(rumored)
        a.health.exclude(private)
        b.health.exclude(private)  # b's own judgment about the same link
        cluster.fabric.run_until(0.001)
        assert b.store.get(rumored).excluded
        a.health.readmit(rumored, verified=True)  # probe succeeded
        a.health.readmit(private, verified=True)
        cluster.fabric.run_until(0.002)
        assert not b.store.get(rumored).excluded  # rumor lifecycle closed
        assert b.store.get(private).excluded  # peer's own view untouched

    def test_blind_reset_readmission_does_not_close_rumor(self):
        """The origin's periodic state reset re-admits excluded rails
        without probing; that must not clear the failure rumor cluster-wide
        mid-outage — only a probe-verified readmission gossips."""
        cluster = _two_engine_cluster(diffusion=True, gossip_delay=0.0005)
        a, b = cluster.engines["a"], cluster.engines["b"]
        lid = cluster.topology.rdma_nic(1, 4).link_id
        a.health.on_explicit_failure(lid)
        cluster.fabric.run_until(0.001)
        assert b.store.get(lid).excluded
        a.health.readmit(lid)  # what the reset timer does: unverified
        cluster.fabric.run_until(0.002)
        assert b.store.get(lid).excluded  # rumor stands until a probe passes
        a.health.exclude(lid, explicit=True)  # origin re-observes the outage
        a.health.readmit(lid, verified=True)  # ... and later probes it back
        cluster.fabric.run_until(0.003)
        assert not b.store.get(lid).excluded

    def test_explicit_failure_on_implicitly_excluded_link_still_gossips(self):
        """An implicit (slow-rail) exclusion escalating to a wire error is
        news the cluster has not heard; the rumor must still go out."""
        cluster = _two_engine_cluster(diffusion=True, gossip_delay=0.0005)
        a, b = cluster.engines["a"], cluster.engines["b"]
        lid = cluster.topology.rdma_nic(1, 5).link_id
        a.health.exclude(lid)  # implicit: local congestion estimate
        assert cluster.membership.rumors_sent == 0
        a.health.on_explicit_failure(lid)  # the link then hard-fails
        assert cluster.membership.rumors_sent == 1
        a.health.on_explicit_failure(lid)  # repeat failures: one rumor only
        assert cluster.membership.rumors_sent == 1
        cluster.fabric.run_until(0.001)
        assert b.store.get(lid).excluded

    def test_peer_readmission_cannot_close_anothers_rumor(self):
        """A peer's periodic reset (or local judgment) readmitting a
        rumor-excluded link must not clear the failure rumor cluster-wide:
        only the observing origin closes the lifecycle."""
        cluster = _two_engine_cluster(diffusion=True, gossip_delay=0.0005)
        a, b = cluster.engines["a"], cluster.engines["b"]
        lid = cluster.topology.rdma_nic(1, 3).link_id
        a.health.on_explicit_failure(lid)
        cluster.fabric.run_until(0.001)
        assert b.store.get(lid).excluded
        sent = cluster.membership.rumors_sent
        b.health.readmit(lid)  # what b's reset timer would do mid-outage
        cluster.fabric.run_until(0.002)
        assert cluster.membership.rumors_sent == sent  # no readmit gossip
        assert a.store.get(lid).excluded  # the observer's view is intact

    def test_staleness_must_cover_the_diffusion_period(self):
        from repro.scenarios import ClusterWorkload

        with pytest.raises(ValueError, match="staleness"):
            ClusterParams(diffusion_period=0.05, diffusion_staleness=0.02)
        with pytest.raises(ValueError, match="staleness"):
            ClusterWorkload(diffusion_period=0.05, diffusion_staleness=0.02)
        with pytest.raises(ValueError, match="staleness"):
            ClusterParams(diffusion_staleness=0.0)  # would drop every entry

    def test_rumor_refresh_regossips_unclosed_outages(self):
        """A rumor that never got closed (no probe-verified readmit) must
        not suppress failure news forever: after `rumor_refresh` a fresh
        explicit observation re-gossips, re-protecting peers whose blind
        resets readmitted the still-dead link."""
        cluster = _two_engine_cluster(diffusion=True, gossip_delay=0.0005)
        cluster.membership.rumor_refresh = 0.01
        a, b = cluster.engines["a"], cluster.engines["b"]
        lid = cluster.topology.rdma_nic(1, 6).link_id
        a.health.on_explicit_failure(lid)
        a.health.on_explicit_failure(lid)  # same outage: suppressed
        assert cluster.membership.rumors_sent == 1
        cluster.fabric.run_until(0.02)
        b.health.readmit(lid)  # b's blind reset re-admits the dead link
        a.health.on_explicit_failure(lid)  # refresh window passed
        assert cluster.membership.rumors_sent == 2
        cluster.fabric.run_until(0.03)
        assert b.store.get(lid).excluded  # peer re-protected

    def test_rumor_application_does_not_echo(self):
        cluster = _two_engine_cluster(diffusion=True, gossip_delay=0.0005)
        a = cluster.engines["a"]
        a.health.on_explicit_failure(cluster.topology.rdma_nic(1, 0).link_id)
        cluster.run_until_idle()  # would livelock if rumors echoed forever
        assert cluster.membership.rumors_sent == 1
        assert cluster.membership.rumors_applied == 1


# ---------------------------------------------------------------------------
# The ISSUE acceptance claims, asserted directly on the scenario reports
# ---------------------------------------------------------------------------


class TestClusterScenarios:
    def test_incast_diffusion_on_strictly_beats_off_and_baselines(self):
        rep = ScenarioRunner(get("multi_engine_kv_incast")).run()
        assert rep.ok, rep.violations
        on = rep.policies["tent+diffusion"].throughput
        off = rep.policies["tent"].throughput
        rr = rep.policies["round_robin"].throughput
        assert on > 1.15 * off  # silo elimination is worth real throughput
        assert on > rr and off > rr
        assert rep.policies["tent+diffusion"].extra["diffusion_rounds"] > 0

    def test_cluster_flap_heals_within_virtual_50ms_via_rumors(self):
        rep = ScenarioRunner(get("multi_engine_incast_flap")).run()
        assert rep.ok, rep.violations
        r = rep.policies["tent+diffusion"]
        assert 0 <= r.stall_ms < 50.0
        assert r.extra["rumors_sent"] > 0 and r.extra["rumors_applied"] > 0
        assert r.retries > 0

    def test_every_engine_audits_zero_lost_slices(self):
        spec = get("multi_engine_kv_incast")
        cluster = ScenarioRunner(spec).build_cluster("tent+diffusion")
        _, ignore = run_cluster_workload(cluster, spec.workload)
        for name, audit in cluster.audit(ignore=ignore).items():
            assert audit["slices_outstanding"] == 0, name
            assert audit["batches_failed"] == 0, name

    def test_broadcast_diffusion_on_leads(self):
        rep = ScenarioRunner(get("trainer_broadcast_fanout")).run()
        assert rep.ok, rep.violations
        on = rep.policies["tent+diffusion"].throughput
        assert on > rep.policies["tent"].throughput
        assert on > rep.policies["round_robin"].throughput

    def test_unknown_policy_flag_rejected(self):
        with pytest.raises(ValueError, match="policy flag"):
            ScenarioRunner(get("multi_engine_kv_incast")).build_cluster("tent+diffuson")

    def test_cluster_rejects_background_tenant_streams(self):
        from repro.scenarios import BackgroundSpec

        spec = dataclasses.replace(
            get("multi_engine_kv_incast"),
            background=BackgroundSpec(tenant_streams=2))
        with pytest.raises(ValueError, match="tenant_streams"):
            ScenarioRunner(spec).build_cluster("tent")

    def test_diffusion_off_policy_runs_without_control_plane(self):
        spec = get("multi_engine_kv_incast")
        cluster = ScenarioRunner(spec).build_cluster("tent")
        assert cluster.diffusion is None and cluster.membership is None
        assert all(e.store.global_weight == 0.0 for e in cluster.engines.values())

    def test_portability_scenarios_ride_their_fabric(self):
        r = ScenarioRunner(get("mnnvl_rack_kv")).run().policies["tent"]
        assert r.extra["bytes_mnnvl"] > 0
        assert r.extra["bytes_mnnvl"] > 10 * r.extra["bytes_rdma"]
        r = ScenarioRunner(get("ascend_ub_kv")).run().policies["tent"]
        assert r.extra["bytes_ub"] > 0
        assert r.extra["bytes_ub"] > 10 * r.extra["bytes_rdma"]

    def test_cluster_workload_round_trips(self):
        spec = get("multi_engine_kv_incast")
        d = spec.to_dict()
        assert d["workload"]["kind"] == "cluster"
        from repro.scenarios import ScenarioSpec

        assert ScenarioSpec.from_dict(d) == spec


# ---------------------------------------------------------------------------
# The modeled gossip channel and partial membership views
# ---------------------------------------------------------------------------


class TestGossipChannel:
    def test_zero_loss_zero_delay_delivers_synchronously(self):
        cluster = _two_engine_cluster(diffusion=True)
        ch = GossipChannel(cluster.fabric)
        hits = []
        assert ch.send(lambda: hits.append(cluster.fabric.now))
        assert hits == [0.0]  # no event loop round trip, PR 2's direct path
        assert (ch.sent, ch.dropped, ch.delivered) == (1, 0, 1)

    def test_delay_schedules_on_the_virtual_clock(self):
        cluster = _two_engine_cluster(diffusion=True)
        ch = GossipChannel(cluster.fabric, delay=0.003)
        hits = []
        ch.send(lambda: hits.append(cluster.fabric.now), extra_delay=0.001)
        assert hits == []  # in flight
        cluster.fabric.run_until(0.01)
        assert hits == [pytest.approx(0.004)]  # delay + extra_delay

    def test_loss_drops_deterministically(self):
        cluster = _two_engine_cluster(diffusion=True)
        ch = GossipChannel(cluster.fabric, loss=NEAR_TOTAL_LOSS, seed=3)
        hits = []
        for _ in range(20):
            ch.send(lambda: hits.append(1))
        cluster.fabric.run_until(1.0)
        assert hits == [] and ch.dropped == 20
        again = GossipChannel(cluster.fabric, loss=0.5, seed=3)
        pattern = [again.send(lambda: None) for _ in range(20)]
        rerun = GossipChannel(cluster.fabric, loss=0.5, seed=3)
        assert pattern == [rerun.send(lambda: None) for _ in range(20)]

    def test_parameter_validation(self):
        fabric = _two_engine_cluster(diffusion=True).fabric
        with pytest.raises(ValueError, match="loss"):
            GossipChannel(fabric, loss=1.0)
        with pytest.raises(ValueError, match="delay"):
            GossipChannel(fabric, delay=-0.001)
        with pytest.raises(ValueError, match="gossip_loss"):
            ClusterParams(gossip_loss=1.5)
        with pytest.raises(ValueError, match="gossip_link_delay"):
            ClusterParams(gossip_link_delay=-1.0)
        with pytest.raises(ValueError, match="arrives stale"):
            ClusterParams(gossip_link_delay=0.05)  # delay + period > staleness
        from repro.scenarios import ClusterWorkload

        with pytest.raises(ValueError, match="gossip_loss"):
            ClusterWorkload(gossip_loss=-0.1)
        with pytest.raises(ValueError, match="arrives stale"):
            ClusterWorkload(gossip_link_delay=0.05)


class TestPeerSampler:
    def test_full_view_by_default(self):
        s = PeerSampler()
        for n in ("a", "b", "c"):
            s.add(n)
        assert s.view("a") == ("b", "c")
        assert s.peers_of("b") == ("a", "c")

    def test_fanout_limits_and_respects_roster(self):
        s = PeerSampler(fanout=2, seed=1)
        for n in ("a", "b", "c", "d", "e"):
            s.add(n)
        for _ in range(10):
            v = s.view("a")
            assert len(v) == 2 and "a" not in v
        s.remove("b")
        assert all("b" not in s.view("a") for _ in range(10))
        # fanout covering the roster degenerates to the full view, no RNG
        wide = PeerSampler(fanout=99, seed=1)
        for n in ("a", "b", "c"):
            wide.add(n)
        assert wide.view("a") == ("b", "c")

    def test_anti_entropy_partner_rotates(self):
        s = PeerSampler()
        for n in ("a", "b", "c"):
            s.add(n)
        seen = {s.anti_entropy_partner("a") for _ in range(4)}
        assert seen == {"b", "c"}
        lone = PeerSampler()
        lone.add("solo")
        assert lone.anti_entropy_partner("solo") is None


# ---------------------------------------------------------------------------
# Control-plane edge cases: loss + anti-entropy + staleness + churn GC
# ---------------------------------------------------------------------------


class TestLossyControlPlane:
    def test_rumor_lost_then_recovered_via_anti_entropy(self):
        """A dropped rumor leaves a peer unprotected; the next anti-entropy
        push reconciles the replica and applies the exclusion."""
        cluster = _two_engine_cluster(diffusion=True)
        a, b = cluster.engines["a"], cluster.engines["b"]
        lid = cluster.topology.rdma_nic(1, 2).link_id
        cluster.channel.loss = NEAR_TOTAL_LOSS  # the rumor will be dropped
        a.health.on_explicit_failure(lid)
        cluster.fabric.run_until(0.01)
        assert cluster.membership.rumors_sent == 1
        assert cluster.channel.dropped >= 1
        assert not b.store.get(lid).excluded  # the gap loss opened
        cluster.channel.loss = 0.0  # the next reconciliation gets through
        cluster.membership.run_anti_entropy()
        cluster.fabric.run_until(0.02)
        assert b.store.get(lid).excluded  # anti-entropy closed the gap
        assert cluster.membership.anti_entropy_repairs >= 1

    def test_anti_entropy_does_not_reimpose_blind_reset_divergence(self):
        """A peer whose blind reset readmitted a rumored link diverges in
        health *state* only — its replica still holds the rumor record, so
        anti-entropy (same version, no news) must not re-exclude it."""
        cluster = _two_engine_cluster(diffusion=True)
        a, b = cluster.engines["a"], cluster.engines["b"]
        lid = cluster.topology.rdma_nic(1, 1).link_id
        a.health.on_explicit_failure(lid)
        cluster.fabric.run_until(0.005)
        assert b.store.get(lid).excluded
        b.health.readmit(lid)  # b's periodic blind reset, mid-outage
        for _ in range(5):
            cluster.membership.run_anti_entropy()
        cluster.fabric.run_until(0.01)
        assert not b.store.get(lid).excluded  # PR 2 semantics preserved

    def test_dropped_telemetry_round_honors_staleness_bound(self):
        """When rounds are lost, a receiver schedules on its last delivered
        snapshot only while that snapshot is inside the staleness horizon —
        never on older ghosts."""
        cluster = _two_engine_cluster(diffusion=True, diffusion_staleness=0.01)
        a, b = cluster.engines["a"], cluster.engines["b"]
        lid = cluster.topology.rdma_nic(0, 0).link_id
        a.store.get(lid).queued_bytes = 777
        cluster.diffusion.publish()
        cluster.diffusion.diffuse()
        assert b.store.global_load == {lid: 777}  # delivered, fresh
        cluster.channel.loss = NEAR_TOTAL_LOSS  # every later round drops
        cluster.fabric.run_until(0.005)  # inside the horizon
        cluster.diffusion.publish()
        cluster.diffusion.diffuse()
        assert b.store.global_load == {lid: 777}  # stale-but-valid survives
        cluster.fabric.run_until(0.5)  # way past the horizon
        cluster.diffusion.publish()
        cluster.diffusion.diffuse()
        assert b.store.global_load == {}  # the bound is honored

    def test_late_delivery_cannot_overwrite_fresher_snapshot(self):
        cluster = _two_engine_cluster(diffusion=True)
        a, b = cluster.engines["a"], cluster.engines["b"]
        lid = cluster.topology.rdma_nic(0, 0).link_id
        table = cluster.diffusion
        table._receive("b", "a", t=0.002, snap={lid: 20})  # fresher, first
        table._receive("b", "a", t=0.001, snap={lid: 10})  # reordered arrival
        assert table._tables["b"]["a"] == (0.002, {lid: 20})

    def test_lossy_channel_is_deterministic(self):
        spec = get("lossy_gossip_flap")
        r1 = ScenarioRunner(spec).run().to_json(sort_keys=True)
        r2 = ScenarioRunner(spec).run().to_json(sort_keys=True)
        assert r1 == r2

    def test_full_fanout_matches_default_broadcast_exactly(self):
        """fanout >= roster degenerates to the full view without RNG draws:
        the physics must be identical to the default broadcast."""
        spec = get("multi_engine_kv_incast")
        wide = dataclasses.replace(
            spec, workload=dataclasses.replace(spec.workload, fanout=99))
        a = ScenarioRunner(spec).run().to_dict()["policies"]
        b = ScenarioRunner(wide).run().to_dict()["policies"]
        assert a == b


class TestEngineChurn:
    def test_add_engine_validates_ownership(self):
        cluster = TentCluster(
            FabricSpec(n_nodes=3), [EngineRole("a", (0,)), EngineRole("b", (1,))])
        with pytest.raises(ValueError, match="already used"):
            cluster.add_engine("a", (2,))
        with pytest.raises(ValueError, match="owned by both"):
            cluster.add_engine("c", (1,))
        with pytest.raises(ValueError, match="outside"):
            cluster.add_engine("c", (9,))
        with pytest.raises(KeyError):
            cluster.remove_engine("nope")

    def test_join_wires_services_and_leave_releases_nodes(self):
        cluster = TentCluster(
            FabricSpec(n_nodes=3), [EngineRole("a", (0,)), EngineRole("b", (1,))],
            params=ClusterParams(diffusion=True, global_weight=0.7))
        c = cluster.add_engine("c", (2,))
        assert c.store.global_weight == 0.7  # omega handed to the joiner
        assert cluster.engine_for_node(2) is c
        assert "c" in cluster.membership.members()
        cluster.remove_engine("c")
        assert "c" not in cluster.engines and "c" in cluster.departed
        assert "c" not in cluster.membership.members()
        late = cluster.add_engine("late", (2,))  # released node is reusable
        assert cluster.engine_for_node(2) is late
        with pytest.raises(ValueError, match="already used"):
            cluster.add_engine("c", (2,))  # departed names stay reserved

    def test_departed_engine_entries_are_garbage_collected(self):
        """The satellite claim: a leaver's published footprint (including
        receiver-side remote_queued charges) must vanish from every peer's
        global view immediately, not at the staleness horizon."""
        cluster = _two_engine_cluster(diffusion=True)
        a, b = cluster.engines["a"], cluster.engines["b"]
        lid = cluster.topology.rdma_nic(1, 0).link_id
        a.store.charge_remote(lid, 4096)  # a's in-flight charge on b's NIC
        cluster.diffusion.publish()
        cluster.diffusion.diffuse()
        assert b.store.global_load == {lid: 4096}  # the pressure is visible
        cluster.remove_engine("a")
        assert b.store.global_load == {}  # ...and GC'd the moment a leaves
        assert a.store.global_load == {}  # the leaver forgets the cluster too
        cluster.diffusion.publish()
        cluster.diffusion.diffuse()
        assert b.store.global_load == {}  # no resurrection on later rounds

    def test_joiner_learns_open_rumors_via_anti_entropy(self):
        """A cold joiner holds no rumor state; reconciliation pushes from
        established members must protect it from a known-dead link."""
        cluster = TentCluster(
            FabricSpec(n_nodes=3), [EngineRole("a", (0,)), EngineRole("b", (1,))],
            params=ClusterParams(diffusion=True))
        a = cluster.engines["a"]
        lid = cluster.topology.rdma_nic(1, 3).link_id
        a.health.on_explicit_failure(lid)
        cluster.fabric.run_until(0.005)
        c = cluster.add_engine("c", (2,))  # joins after the outage was rumored
        assert not c.store.get(lid).excluded  # cold: no instant bootstrap
        for _ in range(4):  # rotation reaches the joiner within a few rounds
            cluster.membership.run_anti_entropy()
        cluster.fabric.run_until(0.01)
        assert c.store.get(lid).excluded

    def test_rumors_to_departed_engines_drop_on_the_floor(self):
        cluster = TentCluster(
            FabricSpec(n_nodes=3),
            [EngineRole("a", (0,)), EngineRole("b", (1,)), EngineRole("c", (2,))],
            params=ClusterParams(diffusion=True, gossip_link_delay=0.002))
        a, b = cluster.engines["a"], cluster.engines["b"]
        lid = cluster.topology.rdma_nic(1, 5).link_id
        a.health.on_explicit_failure(lid)  # rumor in flight to b and c
        cluster.remove_engine("b")  # b departs before delivery
        cluster.fabric.run_until(0.01)
        assert not b.store.get(lid).excluded  # the in-flight rumor was void
        assert cluster.engines["c"].store.get(lid).excluded  # c still got it

    def test_join_after_quiet_gap_rearms_diffusion(self):
        """If the cluster drained and the diffusion timer quiesced before a
        join, the joiner must still get diffusion rounds (and anti-entropy)
        once it has work — '+diffusion' must not silently degrade to silos."""
        cluster = TentCluster(
            FabricSpec(n_nodes=3), [EngineRole("a", (0,)), EngineRole("b", (1,))],
            params=ClusterParams(diffusion=True))
        e = cluster.engines["a"]
        src = e.register_segment(host_loc(0, 0), 1 << 20, materialize=False)
        dst = e.register_segment(host_loc(1, 0), 1 << 20, materialize=False)
        bid = e.allocate_batch()
        e.submit_transfer(bid, [(src.segment_id, 0, dst.segment_id, 0, 1 << 20)])
        cluster.start()
        cluster.run_until_idle()  # work drains; the timer disarms
        rounds = cluster.diffusion.rounds
        c = cluster.add_engine("c", (2,))
        src = c.register_segment(host_loc(2, 0), 8 << 20, materialize=False)
        dst = c.register_segment(host_loc(1, 0), 8 << 20, materialize=False)
        bid = c.allocate_batch()
        c.submit_transfer(bid, [(src.segment_id, 0, dst.segment_id, 0, 8 << 20)])
        cluster.run_until_idle()
        assert cluster.diffusion.rounds > rounds  # the join re-armed it

    def test_roles_track_membership_through_churn(self):
        cluster = TentCluster(
            FabricSpec(n_nodes=3), [EngineRole("a", (0,)), EngineRole("b", (1,))])
        cluster.remove_engine("b")
        cluster.add_engine("c", (1,))
        assert [r.name for r in cluster.roles] == ["a", "c"]
        owned = [n for r in cluster.roles for n in r.nodes]
        assert len(owned) == len(set(owned))  # no stale ownership claims

    def test_leaver_health_hooks_are_unhooked(self):
        cluster = _two_engine_cluster(diffusion=True)
        a = cluster.engines["a"]
        cluster.remove_engine("a")
        sent = cluster.membership.rumors_sent
        a.health.on_explicit_failure(cluster.topology.rdma_nic(0, 0).link_id)
        assert cluster.membership.rumors_sent == sent  # no gossip from ghosts


# ---------------------------------------------------------------------------
# The ISSUE acceptance claims for the lossy/churning control plane
# ---------------------------------------------------------------------------


class TestLossyChurnScenarios:
    def test_lossy_gossip_flap_heals_within_50ms(self):
        """20% loss + 5 ms delivery delay on every control message: the wire
        failure must still heal cluster-wide inside the 50 ms budget, with
        anti-entropy visibly doing repair work."""
        rep = ScenarioRunner(get("lossy_gossip_flap")).run()
        assert rep.ok, rep.violations
        r = rep.policies["tent+diffusion"]
        assert 0 <= r.stall_ms < 50.0
        assert r.extra["gossip_dropped"] > 0  # the loss model really fired
        assert r.extra["rumors_applied"] > 0
        assert r.extra["anti_entropy_repairs"] > 0  # reconciliation worked
        assert r.throughput > 1.1 * rep.policies["tent"].throughput

    def test_engine_churn_diffusion_on_beats_off(self):
        """One engine leaves and one joins mid-run; the control plane keeps
        paying for itself >= 1.10x against the siloed baseline."""
        rep = ScenarioRunner(get("engine_churn_diffusion")).run()
        assert rep.ok, rep.violations
        on = rep.policies["tent+diffusion"]
        assert on.throughput >= 1.10 * rep.policies["tent"].throughput
        assert on.extra["engines_joined"] == 1 and on.extra["engines_left"] == 1
        assert on.lost_slices == 0

    def test_churn_run_audits_clean_on_every_engine_including_departed(self):
        spec = get("engine_churn_diffusion")
        cluster = ScenarioRunner(spec).build_cluster("tent+diffusion")
        churn = tuple(f for f in spec.faults if f.is_churn)
        _, ignore = run_cluster_workload(cluster, spec.workload, churn)
        assert "prefill2" in cluster.departed and "prefill5" in cluster.engines
        audit = cluster.audit(ignore=ignore)
        for name, a in audit.items():
            assert a["slices_outstanding"] == 0, name
            assert a["batches_failed"] == 0, name
        assert audit["prefill2"]["batches_done"] > 0  # leaver's work counted
        assert audit["prefill5"]["batches_done"] > 0  # joiner really produced

    def test_partial_view_incast_still_pays_for_diffusion(self):
        rep = ScenarioRunner(get("partial_view_incast")).run()
        assert rep.ok, rep.violations
        on = rep.policies["tent+diffusion"]
        assert on.throughput >= 1.10 * rep.policies["tent"].throughput

    def test_churn_events_round_trip_and_validate(self):
        from repro.scenarios import FaultEvent, ScenarioSpec

        spec = get("engine_churn_diffusion")
        assert ScenarioSpec.from_dict(spec.to_dict()) == spec
        with pytest.raises(ValueError, match="engine name"):
            FaultEvent("leave", 0, 0, at=0.01)
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultEvent("evaporate", 0, 0, at=0.01, until=0.02)
        assert engine_join("x", 1, at=0.5).is_churn
        assert not FaultEvent("fail", 0, 0, at=0.1, until=0.2).is_churn

    def test_single_engine_workload_rejects_churn_events(self):
        single = dataclasses.replace(
            get("single_rail_flap"),
            faults=(engine_leave("prefill0", at=0.01),))
        with pytest.raises(ValueError, match="cluster workload"):
            ScenarioRunner(single).build_engine("tent")
