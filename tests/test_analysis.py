"""tentlint tests: per-rule fixture pins, suppression/baseline round-trips,
fingerprint stability, CLI exit codes, the @hot_path marker, and the
REPRO_SANITIZE runtime sanitizer."""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import hot_path, is_hot_path
from repro.analysis.baseline import Baseline, apply_baseline
from repro.analysis.core import Project, run_rules
from repro.analysis.lint import DEFAULT_PATHS, main, run_lint
from repro.analysis.rules import ALL_RULES, RULES_BY_ID, default_rules
from repro.analysis.sanitize import (
    SanitizerError,
    enabled,
    maybe_sanitized,
    sanitized,
)

FIXTURES = Path(__file__).resolve().parent / "fixtures" / "lint"
REPO_ROOT = Path(__file__).resolve().parents[1]


def lint_fixture(name, rules=None):
    """Lint one fixture file as if it were engine source."""
    project = Project(FIXTURES, [FIXTURES / name], src_prefixes=("",),
                      test_markers=("tests/",))
    return run_rules(project, default_rules(rules))


# ---------------------------------------------------------------------------
# per-rule fixture pins: each violation class fails with the right rule id
# ---------------------------------------------------------------------------

class TestRuleFixtures:
    def test_no_wall_clock_flags_bad_fixture(self):
        found = lint_fixture("bad_wall_clock.py", rules=["no-wall-clock"])
        assert len(found) == 4
        assert {f.rule for f in found} == {"no-wall-clock"}
        assert all(f.active for f in found)

    def test_no_wall_clock_passes_clean_fixture(self):
        assert lint_fixture("clean_wall_clock.py",
                            rules=["no-wall-clock"]) == []

    def test_no_global_rng_flags_bad_fixture(self):
        found = lint_fixture("bad_global_rng.py", rules=["no-global-rng"])
        assert {f.rule for f in found} == {"no-global-rng"}
        assert len(found) == 6  # rand, seed, random + id/hash/time seeds
        assert sum("nondeterministic seed" in f.message for f in found) == 3

    def test_no_global_rng_passes_clean_fixture(self):
        assert lint_fixture("clean_global_rng.py",
                            rules=["no-global-rng"]) == []

    def test_fma_hazard_flags_bad_fixture(self):
        found = lint_fixture("bad_fma.py", rules=["fma-hazard"])
        assert {f.rule for f in found} == {"fma-hazard"}
        assert len(found) == 3  # two scan-body products + one jitted blend
        assert not any(f.line > 20 for f in found)  # int product unflagged

    def test_fma_hazard_passes_clean_fixture(self):
        assert lint_fixture("clean_fma.py", rules=["fma-hazard"]) == []

    def test_unordered_iter_flags_bad_fixture(self):
        found = lint_fixture("bad_unordered.py", rules=["unordered-iter"])
        assert {f.rule for f in found} == {"unordered-iter"}
        assert len(found) == 4

    def test_unordered_iter_passes_clean_fixture(self):
        assert lint_fixture("clean_unordered.py",
                            rules=["unordered-iter"]) == []

    def test_hot_path_alloc_flags_bad_fixture(self):
        found = lint_fixture("bad_hotpath.py", rules=["hot-path-alloc"])
        assert {f.rule for f in found} == {"hot-path-alloc"}
        assert len(found) == 4  # lambda, partial, comprehension, nested def

    def test_hot_path_alloc_passes_clean_fixture(self):
        assert lint_fixture("clean_hotpath.py",
                            rules=["hot-path-alloc"]) == []

    def test_twin_drift_mini_project(self):
        root = FIXTURES / "twinproj"
        project = Project(
            root, [root / "kernels.py", root / "tests" / "test_parity.py"],
            src_prefixes=("",), test_markers=("tests/",))
        found = run_rules(project, default_rules(["twin-drift"]))
        assert {f.rule for f in found} == {"twin-drift"}
        by_msg = {f.message.split("`")[1]: f.message for f in found}
        assert set(by_msg) == {"drifted_jnp", "orphan_jnp", "untested_jnp"}
        assert "drifted" in by_msg["drifted_jnp"]  # signature drift
        assert "no numpy twin" in by_msg["orphan_jnp"]
        assert "no parity test" in by_msg["untested_jnp"]


# ---------------------------------------------------------------------------
# suppression pragmas
# ---------------------------------------------------------------------------

class TestSuppression:
    def test_line_and_file_pragmas(self):
        found = lint_fixture("suppressed.py",
                             rules=["no-wall-clock", "no-global-rng"])
        active = [f for f in found if f.active]
        suppressed = [f for f in found if f.suppressed]
        assert len(active) == 1
        assert "perf_counter" in active[0].message
        assert len(suppressed) == 2  # line pragma + disable-file pragma

    def test_pragma_in_string_literal_is_ignored(self, tmp_path):
        f = tmp_path / "strings.py"
        f.write_text(
            's = "# tentlint: disable-file=no-wall-clock"\n'
            "import time\n\n\n"
            "def g():\n    return time.time()\n")
        project = Project(tmp_path, [f], src_prefixes=("",))
        found = run_rules(project, default_rules(["no-wall-clock"]))
        assert len(found) == 1 and found[0].active


# ---------------------------------------------------------------------------
# baseline round-trip + fingerprint stability
# ---------------------------------------------------------------------------

class TestBaseline:
    def _findings(self):
        return lint_fixture("bad_wall_clock.py", rules=["no-wall-clock"])

    def test_round_trip_accepts_then_detects_staleness(self, tmp_path):
        found = self._findings()
        bl = Baseline.from_findings(found)
        path = tmp_path / "baseline.json"
        bl.save(path)
        reloaded = Baseline.load(path)
        assert reloaded.by_fp.keys() == bl.by_fp.keys()

        marked, stale = apply_baseline(found, reloaded)
        assert all(f.baselined for f in marked)
        assert not any(f.active for f in marked)
        assert stale == []

        # against a clean file every entry is stale (debt paid down)
        clean = lint_fixture("clean_wall_clock.py", rules=["no-wall-clock"])
        _, stale = apply_baseline(clean, reloaded)
        assert len(stale) == len(bl.entries)

    def test_reasons_carry_forward(self, tmp_path):
        found = self._findings()
        old = Baseline.from_findings(found)
        for e in old.entries:
            e["reason"] = "justified: " + e["rule"]
        old = Baseline(old.entries)
        new = Baseline.from_findings(found, old)
        assert all(e["reason"].startswith("justified:") for e in new.entries)

    def test_fingerprints_survive_line_drift(self, tmp_path):
        found = self._findings()
        shifted = tmp_path / "bad_wall_clock.py"  # same basename on purpose
        original = (FIXTURES / "bad_wall_clock.py").read_text()
        shifted.write_text("# pushed\n# down\n# by\n# comments\n" + original)
        project = Project(tmp_path, [shifted], src_prefixes=("",))
        drifted = run_rules(project, default_rules(["no-wall-clock"]))
        assert {f.fingerprint for f in drifted} == \
            {f.fingerprint for f in found}
        assert {f.line for f in drifted} != {f.line for f in found}

    def test_bad_version_rejected(self, tmp_path):
        p = tmp_path / "b.json"
        p.write_text(json.dumps({"version": 99, "findings": []}))
        with pytest.raises(ValueError):
            Baseline.load(p)


# ---------------------------------------------------------------------------
# CLI + whole-tree gate
# ---------------------------------------------------------------------------

class TestCli:
    def test_list_rules_exits_zero(self, capsys):
        assert main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for rule in ALL_RULES:
            assert rule.id in out

    def test_unknown_rule_is_usage_error(self):
        assert main(["--rules", "no-such-rule",
                     "--root", str(FIXTURES)]) == 2

    def test_violation_file_fails(self, capsys):
        # no-global-rng applies to every file, so it fires through the CLI
        # even though the fixture sits outside the src/repro prefix
        rc = main([str(FIXTURES / "bad_global_rng.py"),
                   "--root", str(FIXTURES), "--rules", "no-global-rng"])
        assert rc == 1
        assert "[no-global-rng]" in capsys.readouterr().out

    def test_json_report_written(self, tmp_path, capsys):
        out = tmp_path / "report.json"
        rc = main([str(FIXTURES / "bad_global_rng.py"),
                   "--root", str(FIXTURES), "--rules", "no-global-rng",
                   "--json", str(out)])
        assert rc == 1
        report = json.loads(out.read_text())
        assert report["counts"]["active"] == 6
        assert all(f["rule"] == "no-global-rng"
                   for f in report["findings"])

    def test_write_baseline_then_strict_passes(self, tmp_path, capsys):
        bl = tmp_path / "baseline.json"
        bad = str(FIXTURES / "bad_global_rng.py")
        common = [bad, "--root", str(FIXTURES), "--rules", "no-global-rng",
                  "--baseline", str(bl)]
        assert main(common) == 1
        assert main(common + ["--write-baseline"]) == 0
        assert main(common + ["--strict"]) == 0  # all baselined, none stale

    def test_full_tree_is_clean(self, capsys):
        """The acceptance gate, in-process: the committed tree must lint
        clean under every rule with the committed baseline."""
        paths = [REPO_ROOT / p for p in DEFAULT_PATHS
                 if (REPO_ROOT / p).exists()]
        findings, stale, project = run_lint(
            REPO_ROOT, paths,
            baseline_path=REPO_ROOT / "tentlint_baseline.json")
        assert project.errors == []
        assert stale == []
        active = [f for f in findings if f.active]
        assert active == [], "\n".join(
            f"{f.path}:{f.line}: [{f.rule}] {f.message}" for f in active)


# ---------------------------------------------------------------------------
# @hot_path marker
# ---------------------------------------------------------------------------

class TestHotPathMarker:
    def test_identity_preserved_and_tagged(self):
        def f(x):
            return x

        tagged = hot_path(f)
        assert tagged is f  # zero-cost: no wrapper frame
        assert is_hot_path(tagged)
        assert not is_hot_path(lambda: None)

    def test_known_hot_paths_are_tagged(self):
        from repro.core.calqueue import CalendarQueue
        from repro.core.engine import TentEngine
        from repro.core.telemetry import TelemetryStore

        assert is_hot_path(TentEngine._dispatch)
        assert is_hot_path(TentEngine._on_wire_done_many)
        assert is_hot_path(TelemetryStore.on_complete_many)
        assert is_hot_path(CalendarQueue.push)
        assert is_hot_path(CalendarQueue.pop)


# ---------------------------------------------------------------------------
# runtime sanitizer
# ---------------------------------------------------------------------------

def _as_repro_module(stmt: str):
    """Build a zero-arg function whose frame claims to live in a repro.*
    module, so the sanitizer's caller check treats it as engine code."""
    import random
    import time

    ns = {"__name__": "repro.fake.simpath", "time": time, "np": np,
          "random": random}
    exec(f"def f():\n    return {stmt}", ns)
    return ns["f"]


class TestSanitizer:
    def test_enabled_reads_env(self, monkeypatch):
        monkeypatch.delenv("REPRO_SANITIZE", raising=False)
        assert not enabled()
        monkeypatch.setenv("REPRO_SANITIZE", "1")
        assert enabled()
        monkeypatch.setenv("REPRO_SANITIZE", "0")
        assert not enabled()

    @pytest.mark.parametrize("stmt", [
        "time.time()", "time.perf_counter()", "np.random.rand(2)",
        "np.random.seed(0)", "random.random()",
    ])
    def test_repro_caller_raises(self, stmt):
        fn = _as_repro_module(stmt)
        with sanitized():
            with pytest.raises(SanitizerError):
                fn()
        fn_name = stmt.split("(")[0]
        assert fn_name  # and the patch is gone afterwards:
        fn()  # outside the context the same call succeeds

    def test_non_repro_caller_passes_through(self):
        import time
        with sanitized():
            assert isinstance(time.time(), float)  # this module isn't repro.*
            assert np.random.default_rng(0).random() >= 0  # always fine

    def test_allowlisted_repro_module_passes(self):
        import time
        ns = {"__name__": "repro.training.train_loop", "time": time}
        exec("def f():\n    return time.time()", ns)
        with sanitized():
            assert isinstance(ns["f"](), float)

    def test_reentrant_and_restores(self):
        import time
        orig = time.time
        with sanitized():
            with sanitized():  # inner block must not double-patch
                assert getattr(time.time, "__tentlint_stub__", False)
            assert getattr(time.time, "__tentlint_stub__", False)
        assert time.time is orig

    def test_maybe_sanitized_off_is_noop(self, monkeypatch):
        import time
        monkeypatch.delenv("REPRO_SANITIZE", raising=False)
        with maybe_sanitized():
            assert not getattr(time.time, "__tentlint_stub__", False)

    def test_scenario_runs_under_sanitizer(self, monkeypatch):
        """One scenario-library smoke with dynamic enforcement on: the
        whole simulated path must complete without touching the wall clock
        or global RNG, and produce the same report as an unsanitized run."""
        from repro.scenarios import ScenarioRunner, get

        spec = get("single_rail_flap")
        monkeypatch.delenv("REPRO_SANITIZE", raising=False)
        plain = ScenarioRunner(spec).run()
        monkeypatch.setenv("REPRO_SANITIZE", "1")
        guarded = ScenarioRunner(spec).run()
        assert guarded.violations == plain.violations
        for pol, rep in plain.policies.items():
            assert guarded.policies[pol] == rep
