"""Seeded traffic generation: determinism + residency-model properties.

`repro.scenarios.traffic` is the single source of arrival streams for the
`serving_production_stream` scenario, `benchmarks/serving_scale.py`, the
closed-loop serving bench, and the jitted sweep lowering — so every
consumer's reproducibility rests on these pins: the same `TrafficSpec`
must generate bit-identical arrays, and `promotion_bytes` must implement
exactly the group-residency model the batched stepper assumes.
"""
import dataclasses

import numpy as np
import pytest

from repro.scenarios.traffic import (TrafficSpec, conversation_tokens,
                                     promotion_bytes)


def _spec(**kw):
    base = dict(requests=2_000, arrival_rate=200.0, zipf_alpha=1.1,
                groups=64, input_tokens=512, output_tokens=32, seed=42)
    base.update(kw)
    return TrafficSpec(**base)


class TestDeterminism:
    def test_same_seed_identical_arrays(self):
        a, b = _spec().generate(), _spec().generate()
        for f in ("arrival", "group", "input_tokens", "output_tokens"):
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f))

    def test_different_seed_different_stream(self):
        a = _spec(seed=1).generate()
        b = _spec(seed=2).generate()
        assert not np.array_equal(a.arrival, b.arrival)

    def test_promotion_bytes_deterministic(self):
        s = _spec().generate()
        kw = dict(prefix_frac=0.9, kv_bytes_per_token=40_000, resident_s=2.0)
        np.testing.assert_array_equal(promotion_bytes(s, **kw),
                                      promotion_bytes(s, **kw))

    def test_conversation_tokens_deterministic(self):
        a = conversation_tokens(8, 4, 128, seed=3)
        b = conversation_tokens(8, 4, 128, seed=3)
        assert a == b
        assert len(a) == 8 and all(len(v) == 4 * 128 for v in a.values())

    def test_spec_round_trips_through_dict(self):
        spec = _spec()
        assert TrafficSpec.from_dict(dataclasses.asdict(spec)) == spec


class TestStreamShape:
    def test_arrivals_sorted_and_positive(self):
        s = _spec().generate()
        assert np.all(np.diff(s.arrival) >= 0)
        assert s.arrival[0] > 0
        # mean inter-arrival ~ 1/rate (Poisson process, generous tolerance)
        assert s.arrival[-1] / len(s) == pytest.approx(1 / 200.0, rel=0.25)

    def test_zipf_head_dominates(self):
        s = _spec(requests=20_000, groups=128, zipf_alpha=1.2).generate()
        counts = np.bincount(s.group, minlength=128)
        # rank-1 group beats the whole tail half under any real skew
        assert counts[0] > counts[64:].sum()
        assert counts.sum() == 20_000

    def test_input_tokens_floor(self):
        s = _spec(input_tokens=16, input_jitter=2.0).generate()
        assert s.input_tokens.min() >= 16

    def test_empty_stream(self):
        s = _spec(requests=0, arrival_rate=0.0).generate()
        assert len(s) == 0
        assert promotion_bytes(
            s, prefix_frac=0.5, kv_bytes_per_token=1, resident_s=1.0).size == 0

    def test_validation(self):
        with pytest.raises(ValueError):
            _spec(requests=-1)
        with pytest.raises(ValueError):
            _spec(arrival_rate=0.0)
        with pytest.raises(ValueError):
            _spec(zipf_alpha=0.0)
        with pytest.raises(ValueError):
            _spec(groups=0)


class TestPromotionModel:
    def test_first_touch_always_promotes(self):
        s = _spec().generate()
        promo = promotion_bytes(s, prefix_frac=0.9, kv_bytes_per_token=1_000,
                                resident_s=1e9)
        # with infinite residency each group pays exactly once
        promoted_groups = np.unique(s.group[promo > 0])
        np.testing.assert_array_equal(promoted_groups, np.unique(s.group))
        assert int((promo > 0).sum()) == np.unique(s.group).size

    def test_zero_residency_promotes_everything(self):
        s = _spec().generate()
        promo = promotion_bytes(s, prefix_frac=1.0, kv_bytes_per_token=7,
                                resident_s=0.0)
        # gaps are continuous-positive, so every request re-promotes
        expect = s.input_tokens * 7
        np.testing.assert_array_equal(promo, expect)

    def test_residency_matches_reference_loop(self):
        """Vectorized lexsort model vs the obvious per-group dict loop."""
        s = _spec(requests=3_000, groups=16, seed=9).generate()
        promo = promotion_bytes(s, prefix_frac=0.5, kv_bytes_per_token=100,
                                resident_s=0.75)
        last_seen: dict = {}
        for i in range(len(s)):
            g, t = int(s.group[i]), float(s.arrival[i])
            cold = g not in last_seen or (t - last_seen[g]) > 0.75
            last_seen[g] = t
            want = (int(np.rint(s.input_tokens[i] * 0.5)) * 100) if cold else 0
            assert promo[i] == want, f"request {i}"

    def test_bytes_scale_with_prefix_frac(self):
        s = _spec().generate()
        lo = promotion_bytes(s, prefix_frac=0.25, kv_bytes_per_token=1_000,
                             resident_s=2.0)
        hi = promotion_bytes(s, prefix_frac=1.0, kv_bytes_per_token=1_000,
                             resident_s=2.0)
        assert hi.sum() > lo.sum()
        np.testing.assert_array_equal(hi > 0, lo > 0)  # same cold set
