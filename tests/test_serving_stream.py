"""Batched production-stream serving mode + streaming percentile sketches.

`ServingSimulator(mode="batched")` advances whole request phases per
virtual-clock tick over the struct-of-arrays `RequestTable`; percentiles
come from P^2 sketches so `ServeSimConfig.log_requests` can default off at
production scale (the unbounded per-request log was the PR-9 bugfix).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import FabricSpec, TentEngine
from repro.serving import ServeSimConfig, ServingSimulator, from_table2
from repro.serving.serve_sim import LOG_AUTO_LIMIT, PH_DONE, RequestTable
from repro.serving.sketch import (EXACT_THRESHOLD, P2Quantile,
                                  PercentileSketch)


def _cfg(**kw):
    """A small, fast stream: enough requests to exercise admission, cohort
    promotion, prefill chunking, and decode, little enough byte volume that
    the whole run is sub-second."""
    base = dict(
        mode="batched", concurrency=64, input_tokens=64, output_tokens=4,
        chunk_tokens=64, stream_requests=2_500, arrival_rate=2_000.0,
        zipf_alpha=1.1, traffic_groups=32, prefix_frac=0.5,
        stream_kv_bytes_per_token=200, resident_s=0.25, tick_s=0.01,
        gpu_node=0, store_node=1, seed=5)
    base.update(kw)
    return ServeSimConfig(**base)


def _run(cfg):
    sim = ServingSimulator(
        TentEngine(FabricSpec()), from_table2(), hicache=None, sim_cfg=cfg)
    return sim, sim.run()


class TestBatchedStream:
    def test_conserves_requests(self):
        sim, st = _run(_cfg())
        assert st.requests == 2_500
        tb = sim._last_table
        assert tb.size == 2_500
        assert np.all(tb.phase[:tb.size] == PH_DONE)
        assert np.all(tb.finish[:tb.size] >= tb.arrival[:tb.size])
        assert st.makespan >= float(tb.finish[:tb.size].max()) - 1e-9

    def test_deterministic_across_fresh_engines(self):
        _, a = _run(_cfg())
        _, b = _run(_cfg())
        for f in ("makespan", "input_throughput", "avg_ttft", "p50_ttft",
                  "p90_ttft", "p99_ttft", "avg_tpot", "p99_tpot",
                  "bytes_promoted", "requests", "serialized_seconds"):
            assert getattr(a, f) == getattr(b, f), f

    def test_seed_changes_stream(self):
        _, a = _run(_cfg(seed=5))
        _, b = _run(_cfg(seed=6))
        assert a.makespan != b.makespan

    def test_ttft_positive_and_ordered(self):
        _, st = _run(_cfg())
        assert 0 < st.p50_ttft <= st.p90_ttft <= st.p99_ttft
        assert st.avg_tpot > 0
        assert st.bytes_promoted > 0

    def test_concurrency_cap_binds(self):
        """A tighter admission cap must not lose requests; queueing happens
        before admission, so (TTFT being admission->first-token, same as the
        async mode's fetch+prefill) the cost surfaces as a longer makespan
        and lower input throughput, not as TTFT."""
        _, wide = _run(_cfg())
        _, narrow = _run(_cfg(concurrency=8))
        assert narrow.requests == wide.requests == 2_500
        assert narrow.makespan > wide.makespan
        assert narrow.input_throughput < wide.input_throughput

    def test_validation(self):
        with pytest.raises(ValueError):
            ServeSimConfig(mode="batched")  # needs stream_requests
        with pytest.raises(ValueError):
            ServeSimConfig(mode="warp-drive")


class TestLogGating:
    """The PR-9 bugfix: the per-request log no longer grows unboundedly at
    production scale — auto-off above LOG_AUTO_LIMIT, and every percentile
    path works without it."""

    def test_auto_threshold(self):
        cfg = _cfg()
        assert dataclasses.replace(
            cfg, stream_requests=LOG_AUTO_LIMIT - 1).keep_log()
        assert not dataclasses.replace(
            cfg, stream_requests=LOG_AUTO_LIMIT).keep_log()
        # explicit settings override the auto rule in both directions
        assert dataclasses.replace(
            cfg, stream_requests=LOG_AUTO_LIMIT * 10,
            log_requests=True).keep_log()
        assert not dataclasses.replace(cfg, log_requests=False).keep_log()

    def test_small_stream_logs_by_default(self):
        _, st = _run(_cfg())
        assert len(st.request_log) == 2_500

    def test_log_off_percentiles_still_work(self):
        _, logged = _run(_cfg())
        _, bare = _run(_cfg(log_requests=False))
        assert bare.request_log == []
        assert bare.requests == logged.requests
        assert bare.makespan == logged.makespan
        assert bare.bytes_promoted == logged.bytes_promoted
        # same stream, so the sketch path must land near the exact path
        # (exact below EXACT_THRESHOLD; P^2 beyond — 2500 > threshold)
        for f in ("p50_ttft", "p90_ttft", "p99_ttft"):
            assert getattr(bare, f) == pytest.approx(
                getattr(logged, f), rel=0.15), f
        assert bare.avg_ttft == pytest.approx(logged.avg_ttft, rel=1e-9)


class TestPercentileSketch:
    def test_exact_below_threshold(self):
        rng = np.random.default_rng(0)
        xs = rng.lognormal(0.0, 1.0, size=EXACT_THRESHOLD - 50)
        sk = PercentileSketch()
        for x in xs:
            sk.add(float(x))
        for q in (50, 90, 99):
            assert sk.percentile(q) == pytest.approx(
                float(np.percentile(xs, q)), rel=1e-12)
        assert sk.count == xs.size
        assert sk.max == pytest.approx(xs.max())
        assert sk.mean == pytest.approx(xs.mean())

    @pytest.mark.parametrize("dist,kw", [
        ("lognormal", dict(mean=0.0, sigma=1.0)),
        ("exponential", dict(scale=3.0)),
        ("uniform", dict(low=0.0, high=10.0)),
    ])
    def test_p2_tracks_numpy_at_scale(self, dist, kw):
        rng = np.random.default_rng(17)
        xs = getattr(rng, dist)(size=50_000, **kw)
        sk = PercentileSketch()
        for x in xs:
            sk.add(float(x))
        for q, tol in ((50, 0.05), (90, 0.05), (99, 0.10)):
            assert sk.percentile(q) == pytest.approx(
                float(np.percentile(xs, q)), rel=tol), f"P{q} on {dist}"

    def test_untracked_quantile_raises_after_buffer_drop(self):
        sk = PercentileSketch()
        for i in range(EXACT_THRESHOLD + 10):
            sk.add(float(i))
        with pytest.raises(ValueError):
            sk.percentile(75)

    def test_empty_sketch(self):
        sk = PercentileSketch()
        assert sk.percentile(99) == 0.0
        assert sk.mean == 0.0

    def test_p2_constant_stream(self):
        p2 = P2Quantile(0.9)
        for _ in range(10_000):
            p2.add(4.25)
        assert p2.value() == pytest.approx(4.25)


class TestRequestTable:
    def test_columns_are_contiguous_and_typed(self):
        tb = RequestTable(128)
        assert tb.phase.dtype == np.int8
        assert tb.arrival.dtype == np.float64
        assert tb.input_tokens.dtype == np.int64
        assert tb.arrival.flags["C_CONTIGUOUS"]

    def test_view_writes_hit_columns(self):
        tb = RequestTable(4)
        req = tb.create(client=7, turn=2)
        req.ttft = 1.5
        assert tb.ttft[req.slot] == 1.5
        assert tb.client[req.slot] == 7
        assert tb.size == 1
