"""Property-based tests (hypothesis) on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dep (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (
    Candidate,
    FabricSpec,
    Location,
    MemoryKind,
    TentEngine,
    TentPolicy,
    TransferRequest,
    decompose,
    tent_choose_jnp,
)
from repro.core.telemetry import LinkTelemetry
from repro.core.topology import LinkDesc
from repro.core.types import LinkClass


def _mk_tl(link_id, bw=25e9, queued=0, beta0=0.0, beta1=1.0, excluded=False):
    desc = LinkDesc(link_id=link_id, node=0, link_class=LinkClass.RDMA,
                    index=link_id, numa=0, bandwidth=bw, base_latency=5e-6)
    tl = LinkTelemetry(desc=desc, beta0=beta0, beta0_prior=beta0, beta1=beta1)
    tl.queued_bytes = queued
    tl.excluded = excluded
    return tl


class TestSliceDecomposition:
    @given(
        length=st.integers(1, 1 << 30),
        src_off=st.integers(0, 1 << 20),
        dst_off=st.integers(0, 1 << 20),
        slice_bytes=st.sampled_from([4096, 65536, 1 << 20]),
        max_slices=st.sampled_from([1, 7, 64, 512]),
    )
    @settings(max_examples=200, deadline=None)
    def test_exact_tiling(self, length, src_off, dst_off, slice_bytes, max_slices):
        req = TransferRequest(
            transfer_id=1, src_segment=1, src_offset=src_off,
            dst_segment=2, dst_offset=dst_off, length=length,
        )
        slices = decompose(req, 1, slice_bytes=slice_bytes, max_slices=max_slices)
        # count bound
        assert 1 <= len(slices) <= max_slices
        # exact, ordered, non-overlapping tiling of [0, length)
        cur_src, cur_dst = src_off, dst_off
        for sl in slices:
            assert sl.src_offset == cur_src and sl.dst_offset == cur_dst
            assert sl.length > 0
            # src/dst offset correspondence preserved
            assert sl.src_offset - src_off == sl.dst_offset - dst_off
            cur_src += sl.length
            cur_dst += sl.length
        assert cur_src - src_off == length


class TestSchedulerInvariants:
    @given(
        queues=st.lists(st.integers(0, 1 << 30), min_size=2, max_size=8),
        tiers=st.lists(st.sampled_from([1, 2]), min_size=2, max_size=8),
        length=st.integers(1, 1 << 24),
        gamma=st.floats(0.0, 0.5),
    )
    @settings(max_examples=200, deadline=None)
    def test_choice_is_within_tolerance_window(self, queues, tiers, length, gamma):
        n = min(len(queues), len(tiers))
        cands = [Candidate(_mk_tl(i, queued=queues[i]), tiers[i]) for i in range(n)]
        policy = TentPolicy(gamma=gamma)
        chosen = policy.choose(cands, length)
        # recompute scores as they were at choice time (chosen was charged)
        scores = []
        for c in cands:
            q = c.telemetry.queued_bytes - (length if c is chosen else 0)
            t_hat = c.telemetry.beta0 + c.telemetry.beta1 * (q + length) / c.telemetry.desc.bandwidth
            scores.append({1: 1.0, 2: 3.0}[c.tier] * t_hat)
        s_min = min(scores)
        s_chosen = scores[cands.index(chosen)]
        assert s_chosen <= (1 + gamma) * s_min * (1 + 1e-9)

    @given(
        queues=st.lists(st.integers(0, 1 << 28), min_size=2, max_size=8),
        length=st.integers(1, 1 << 22),
    )
    @settings(max_examples=100, deadline=None)
    def test_queue_accounting_monotonic(self, queues, length):
        cands = [Candidate(_mk_tl(i, queued=q), 1) for i, q in enumerate(queues)]
        policy = TentPolicy()
        before = sum(c.telemetry.queued_bytes for c in cands)
        policy.choose(cands, length)
        after = sum(c.telemetry.queued_bytes for c in cands)
        assert after == before + length  # Algorithm 1 line 11

    @given(
        queues=st.lists(st.integers(0, 1 << 28), min_size=2, max_size=8),
        length=st.integers(1, 1 << 22),
        rr=st.integers(0, 100),
    )
    @settings(max_examples=50, deadline=None)
    def test_jnp_scorer_matches_python(self, queues, length, rr):
        import jax.numpy as jnp

        n = len(queues)
        cands = [Candidate(_mk_tl(i, queued=q), 1) for i, q in enumerate(queues)]
        policy = TentPolicy()
        s_py = policy.scores(cands, length)
        idx = tent_choose_jnp(
            jnp.asarray(queues, jnp.float32), jnp.full((n,), 25e9, jnp.float32),
            jnp.zeros((n,)), jnp.ones((n,)), jnp.ones((n,)), float(length), rr,
        )
        # the jnp choice must land inside the python tolerance window
        s_min = min(s_py)
        assert s_py[int(idx)] <= 1.05 * s_min * (1 + 1e-6)


class TestEwmaBounded:
    @given(
        obs=st.lists(st.floats(1e-7, 10.0), min_size=1, max_size=50),
        length=st.integers(1, 1 << 24),
    )
    @settings(max_examples=100, deadline=None)
    def test_beta_stays_positive_finite(self, obs, length):
        tl = _mk_tl(0)
        for t_obs in obs:
            tl.on_schedule(length)
            tl.on_complete(length, tl.queued_bytes + length, t_obs)
            assert np.isfinite(tl.beta0) and tl.beta0 >= 0
            assert np.isfinite(tl.beta1) and 0.05 <= tl.beta1 <= 1e4
            assert tl.queued_bytes >= 0
        tl.reset()
        assert tl.beta1 == 1.0 and tl.beta0 == tl.beta0_prior


class TestEndToEndIntegrity:
    @given(
        length=st.integers(1, 4 << 20),
        src_off=st.integers(0, 1 << 16),
        dst_off=st.integers(0, 1 << 16),
        seed=st.integers(0, 2 ** 16),
        policy=st.sampled_from(["tent", "round_robin", "static_best2", "pinned"]),
    )
    @settings(max_examples=20, deadline=None)
    def test_bytes_conserved_any_policy(self, length, src_off, dst_off, seed, policy):
        from repro.core import EngineConfig

        eng = TentEngine(FabricSpec(), config=EngineConfig(policy=policy), seed=seed)
        size = length + max(src_off, dst_off) + 1
        src = eng.register_segment(Location(node=0, kind=MemoryKind.HOST_DRAM), size)
        dst = eng.register_segment(Location(node=1, kind=MemoryKind.HOST_DRAM), size)
        payload = np.random.default_rng(seed).integers(0, 256, length, dtype=np.uint8)
        src.write(src_off, payload)
        res = eng.transfer_sync(src.segment_id, src_off, dst.segment_id, dst_off, length)
        assert res.ok
        np.testing.assert_array_equal(dst.read(dst_off, length), payload)
        # fabric conservation: rdma bytes moved >= payload (retries may add)
        moved = sum(
            l.bytes_completed for l in eng.fabric.links.values()
            if l.desc.node == 0 and l.desc.link_class.value in ("rdma", "tcp")
        )
        assert moved >= length
