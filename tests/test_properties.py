"""Property-based tests (hypothesis) on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dep (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (
    Candidate,
    FabricSpec,
    Location,
    MemoryKind,
    TentEngine,
    TentPolicy,
    TransferRequest,
    decompose,
    tent_choose_jnp,
    tent_choose_wave,
    tent_choose_wave_jnp,
)
from repro.core.telemetry import LinkTelemetry, TelemetryStore
from repro.core.topology import LinkDesc
from repro.core.types import LinkClass

TIER_PENALTY = {1: 1.0, 2: 3.0}


def _mk_tl(link_id, bw=25e9, queued=0, beta0=0.0, beta1=1.0, excluded=False):
    desc = LinkDesc(link_id=link_id, node=0, link_class=LinkClass.RDMA,
                    index=link_id, numa=0, bandwidth=bw, base_latency=5e-6)
    tl = LinkTelemetry(desc=desc, beta0=beta0, beta0_prior=beta0, beta1=beta1)
    tl.queued_bytes = queued
    tl.excluded = excluded
    return tl


class TestSliceDecomposition:
    @given(
        length=st.integers(1, 1 << 30),
        src_off=st.integers(0, 1 << 20),
        dst_off=st.integers(0, 1 << 20),
        slice_bytes=st.sampled_from([4096, 65536, 1 << 20]),
        max_slices=st.sampled_from([1, 7, 64, 512]),
    )
    @settings(max_examples=200, deadline=None)
    def test_exact_tiling(self, length, src_off, dst_off, slice_bytes, max_slices):
        req = TransferRequest(
            transfer_id=1, src_segment=1, src_offset=src_off,
            dst_segment=2, dst_offset=dst_off, length=length,
        )
        slices = decompose(req, 1, slice_bytes=slice_bytes, max_slices=max_slices)
        # count bound
        assert 1 <= len(slices) <= max_slices
        # exact, ordered, non-overlapping tiling of [0, length)
        cur_src, cur_dst = src_off, dst_off
        for sl in slices:
            assert sl.src_offset == cur_src and sl.dst_offset == cur_dst
            assert sl.length > 0
            # src/dst offset correspondence preserved
            assert sl.src_offset - src_off == sl.dst_offset - dst_off
            cur_src += sl.length
            cur_dst += sl.length
        assert cur_src - src_off == length


class TestSchedulerInvariants:
    @given(
        queues=st.lists(st.integers(0, 1 << 30), min_size=2, max_size=8),
        tiers=st.lists(st.sampled_from([1, 2]), min_size=2, max_size=8),
        length=st.integers(1, 1 << 24),
        gamma=st.floats(0.0, 0.5),
    )
    @settings(max_examples=200, deadline=None)
    def test_choice_is_within_tolerance_window(self, queues, tiers, length, gamma):
        n = min(len(queues), len(tiers))
        cands = [Candidate(_mk_tl(i, queued=queues[i]), tiers[i]) for i in range(n)]
        policy = TentPolicy(gamma=gamma)
        chosen = policy.choose(cands, length)
        # recompute scores as they were at choice time (chosen was charged)
        scores = []
        for c in cands:
            q = c.telemetry.queued_bytes - (length if c is chosen else 0)
            t_hat = c.telemetry.beta0 + c.telemetry.beta1 * (q + length) / c.telemetry.desc.bandwidth
            scores.append({1: 1.0, 2: 3.0}[c.tier] * t_hat)
        s_min = min(scores)
        s_chosen = scores[cands.index(chosen)]
        assert s_chosen <= (1 + gamma) * s_min * (1 + 1e-9)

    @given(
        queues=st.lists(st.integers(0, 1 << 28), min_size=2, max_size=8),
        length=st.integers(1, 1 << 22),
    )
    @settings(max_examples=100, deadline=None)
    def test_queue_accounting_monotonic(self, queues, length):
        cands = [Candidate(_mk_tl(i, queued=q), 1) for i, q in enumerate(queues)]
        policy = TentPolicy()
        before = sum(c.telemetry.queued_bytes for c in cands)
        policy.choose(cands, length)
        after = sum(c.telemetry.queued_bytes for c in cands)
        assert after == before + length  # Algorithm 1 line 11

    @given(
        queues=st.lists(st.integers(0, 1 << 28), min_size=2, max_size=8),
        length=st.integers(1, 1 << 22),
        rr=st.integers(0, 100),
    )
    @settings(max_examples=50, deadline=None)
    def test_jnp_scorer_matches_python(self, queues, length, rr):
        import jax.numpy as jnp

        n = len(queues)
        cands = [Candidate(_mk_tl(i, queued=q), 1) for i, q in enumerate(queues)]
        policy = TentPolicy()
        s_py = policy.scores(cands, length)
        idx = tent_choose_jnp(
            jnp.asarray(queues, jnp.float32), jnp.full((n,), 25e9, jnp.float32),
            jnp.zeros((n,)), jnp.ones((n,)), jnp.ones((n,)), float(length), rr,
        )
        # the jnp choice must land inside the python tolerance window
        s_min = min(s_py)
        assert s_py[int(idx)] <= 1.05 * s_min * (1 + 1e-6)

    @given(
        queues=st.lists(st.integers(0, 1 << 28), min_size=2, max_size=8),
        length=st.integers(1, 1 << 22),
        tier=st.integers(1, 3),
    )
    @settings(max_examples=50, deadline=None)
    def test_jnp_scores_bitexact_vs_policy_scores(self, queues, length, tier):
        """tent_scores_jnp under x64 must reproduce TentPolicy.scores
        bit-exactly (same operation order, same roundings)."""
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        from repro.core.scheduler import tent_scores_jnp
        from repro.core.topology import DEFAULT_TIER_PENALTY

        n = len(queues)
        cands = [Candidate(_mk_tl(i, queued=q), tier) for i, q in enumerate(queues)]
        s_py = TentPolicy().scores(cands, length)
        pen = DEFAULT_TIER_PENALTY[tier]
        with enable_x64():
            s_jnp = tent_scores_jnp(
                jnp.asarray(queues, jnp.float64),
                jnp.full((n,), 25e9, jnp.float64),
                jnp.zeros((n,), jnp.float64), jnp.ones((n,), jnp.float64),
                jnp.full((n,), pen, jnp.float64), float(length),
            )
            np.testing.assert_array_equal(np.asarray(s_jnp), np.asarray(s_py))


def _wave_state(draw_queues, tiers, excluded, beta0s, beta1s, global_load, weight):
    """Build one TelemetryStore + candidate list from hypothesis data. Every
    candidate gets a paired remote link (ids offset by 100) so the remote
    pressure/remote exclusion paths are exercised."""
    n = min(len(draw_queues), len(tiers), len(excluded), len(beta0s), len(beta1s))
    store = TelemetryStore()
    cands = []
    for i in range(n):
        desc = LinkDesc(link_id=i, node=0, link_class=LinkClass.RDMA,
                        index=i, numa=0, bandwidth=25e9, base_latency=5e-6)
        rdesc = LinkDesc(link_id=100 + i, node=1, link_class=LinkClass.RDMA,
                         index=i, numa=0, bandwidth=25e9, base_latency=5e-6)
        tl = store.ensure(desc)
        rtl = store.ensure(rdesc)
        tl.queued_bytes = draw_queues[i]
        tl.beta0 = beta0s[i]
        tl.beta1 = beta1s[i]
        tl.excluded = excluded[i]
        # remote exclusions (failure rumors from peers) knock paths out too
        rtl.excluded = excluded[(i + 1) % len(excluded)] and excluded[i - 1]
        cands.append(Candidate(tl, tiers[i], remote=rtl))
    store.global_weight = weight
    store.global_load = {
        lid % (100 + n): q for lid, q in global_load.items()}
    return store, cands


class TestWaveParity:
    """The scalar chooser and the vectorized wave kernels must pick the
    same rail — bit-identical scores, window membership, round-robin tie
    breaks, and sequential line-11 charges — across randomized telemetry
    states including exclusions and omega-blended global load."""

    @given(
        queues=st.lists(st.integers(0, 1 << 30), min_size=2, max_size=8),
        tiers=st.lists(st.sampled_from([1, 2]), min_size=8, max_size=8),
        excluded=st.lists(st.booleans(), min_size=8, max_size=8),
        beta0s=st.lists(st.floats(0.0, 1e-2), min_size=8, max_size=8),
        beta1s=st.lists(st.floats(0.05, 50.0), min_size=8, max_size=8),
        global_load=st.dictionaries(st.integers(0, 120), st.integers(0, 1 << 28),
                                    max_size=6),
        weight=st.sampled_from([0.0, 0.5, 0.6]),
        lengths=st.lists(st.integers(1, 1 << 22), min_size=1, max_size=24),
        rr0=st.integers(0, 50),
        gamma=st.sampled_from([0.0, 0.05, 0.3]),
    )
    @settings(max_examples=120, deadline=None)
    def test_numpy_wave_kernel_replays_scalar_choose(
            self, queues, tiers, excluded, beta0s, beta1s, global_load,
            weight, lengths, rr0, gamma):
        args = (queues, tiers, excluded, beta0s, beta1s, global_load, weight)
        store_a, cands_a = _wave_state(*args)
        store_b, cands_b = _wave_state(*args)
        n = len(cands_a)

        # scalar replay: one choose() per slice, charging as it goes
        policy = TentPolicy(gamma=gamma, store=store_a,
                            tier_penalty=dict(TIER_PENALTY))
        policy._rr = rr0
        scalar_choices = [
            cands_a.index(policy.choose(cands_a, L)) for L in lengths]

        # vectorized replay over the identical twin state
        choices, queued_at, queued_out, rr_out = tent_choose_wave(
            np.asarray([c.telemetry.queued_bytes for c in cands_b]),
            np.asarray([weight * store_b._foreign_load(c.telemetry.desc.link_id)
                        if weight > 0 else 0.0 for c in cands_b]),
            np.asarray([weight * store_b._foreign_load(c.remote.desc.link_id)
                        if weight > 0 else 0.0 for c in cands_b]),
            np.asarray([c.telemetry.desc.bandwidth for c in cands_b]),
            np.asarray([float(c.telemetry.beta0) for c in cands_b]),
            np.asarray([float(c.telemetry.beta1) for c in cands_b]),
            np.asarray([TIER_PENALTY[c.tier] for c in cands_b]),
            np.asarray([bool(c.telemetry.excluded) or bool(c.remote.excluded)
                        for c in cands_b]),
            np.asarray(lengths), rr0, gamma)

        assert list(choices) == scalar_choices
        assert rr_out == policy._rr
        for i in range(n):  # line-11 charges identical after the wave
            assert queued_out[i] == cands_a[i].telemetry.queued_bytes
        # queued_at_schedule (the EWMA anchor) matches the scalar reads
        replay = [int(q) for q in
                  np.asarray([c.telemetry.queued_bytes for c in cands_b])]
        for k, (c, L) in enumerate(zip(choices, lengths)):
            replay[c] += L
            assert queued_at[k] == replay[c]

    @given(
        queues=st.lists(st.integers(0, 1 << 28), min_size=2, max_size=8),
        tiers=st.lists(st.sampled_from([1, 2]), min_size=8, max_size=8),
        excluded=st.lists(st.booleans(), min_size=8, max_size=8),
        length=st.integers(1, 1 << 22),
        rr=st.integers(0, 100),
        gamma=st.sampled_from([0.0, 0.05, 0.3]),
    )
    @settings(max_examples=60, deadline=None)
    def test_jnp_choose_matches_scalar_incl_exclusions_and_ties(
            self, queues, tiers, excluded, length, rr, gamma):
        """tent_choose_jnp under x64 must land on the exact rail the scalar
        policy picks — including soft-excluded rails, the all-excluded
        fallback, and round-robin selection inside the gamma window."""
        from jax.experimental import enable_x64

        n = min(len(queues), len(tiers))
        cands = [Candidate(_mk_tl(i, queued=queues[i], excluded=excluded[i]),
                           tiers[i]) for i in range(n)]
        policy = TentPolicy(gamma=gamma, tier_penalty=dict(TIER_PENALTY))
        policy._rr = rr
        chosen = policy.choose(cands, length)
        scalar_idx = cands.index(chosen)
        with enable_x64():
            idx = tent_choose_jnp(
                np.asarray(queues[:n], dtype=np.float64),
                np.full(n, 25e9), np.zeros(n), np.ones(n),
                np.asarray([TIER_PENALTY[t] for t in tiers[:n]]),
                float(length), rr, gamma,
                excluded=np.asarray(excluded[:n]))
        assert int(idx) == scalar_idx

    @given(
        queues=st.lists(st.integers(0, 1 << 28), min_size=2, max_size=8),
        excluded=st.lists(st.booleans(), min_size=8, max_size=8),
        lengths=st.lists(st.integers(1, 1 << 22), min_size=1, max_size=12),
        rr0=st.integers(0, 50),
        gamma=st.sampled_from([0.0, 0.05]),
    )
    @settings(max_examples=40, deadline=None)
    def test_jnp_wave_kernel_matches_numpy_kernel(
            self, queues, excluded, lengths, rr0, gamma):
        from jax.experimental import enable_x64

        n = len(queues)
        bw = np.full(n, 25e9)
        b0, b1 = np.zeros(n), np.ones(n)
        pen = np.ones(n)
        ex = np.asarray(excluded[:n])
        zeros = np.zeros(n)
        np_c, np_qas, np_q, np_rr = tent_choose_wave(
            np.asarray(queues), zeros, zeros, bw, b0, b1, pen, ex,
            np.asarray(lengths), rr0, gamma)
        with enable_x64():
            j_c, j_qas, j_q, j_rr = tent_choose_wave_jnp(
                np.asarray(queues, dtype=np.float64), zeros, zeros, bw,
                b0, b1, pen, ex, np.asarray(lengths), rr0, gamma)
            # materialize inside the x64 scope (x64 arrays cannot be
            # unstacked once the flag reverts)
            j_c, j_qas, j_q = np.asarray(j_c), np.asarray(j_qas), np.asarray(j_q)
            j_rr = int(j_rr)
        assert list(np_c) == [int(v) for v in j_c]
        assert list(np_qas) == [int(v) for v in j_qas]
        assert list(np_q) == [int(v) for v in j_q]
        assert j_rr == np_rr


def _paired_stores(n_links, queues, beta0s, beta1s):
    """Two identical stores: one takes the scalar per-completion path, the
    other the batched path; every array must come out bit-equal."""
    out = []
    for _ in range(2):
        store = TelemetryStore()
        for i in range(n_links):
            desc = LinkDesc(link_id=i, node=0, link_class=LinkClass.RDMA,
                            index=i, numa=0, bandwidth=25e9, base_latency=5e-6)
            tl = store.ensure(desc)
            tl.queued_bytes = queues[i % len(queues)]
            tl.beta0 = beta0s[i % len(beta0s)]
            tl.beta1 = beta1s[i % len(beta1s)]
        out.append(store)
    return out


_COMPLETE_ARRS = ("beta0_arr", "beta1_arr", "queued_arr", "ewma_service_arr",
                  "completions_arr", "slow_arr", "failures_arr")


class TestCompleteManyParity:
    """`TelemetryStore.on_complete_many` must be **exactly** (bit-for-bit)
    equal to looping `on_complete` over the batch — including repeated slots
    within one batch, where the per-slot EWMA recurrence is order-sensitive
    and the batched path must replay occurrences sequentially."""

    @given(
        n_links=st.integers(1, 6),
        queues=st.lists(st.integers(0, 1 << 30), min_size=1, max_size=6),
        beta0s=st.lists(st.floats(0.0, 1e-2), min_size=1, max_size=6),
        beta1s=st.lists(st.floats(0.05, 50.0), min_size=1, max_size=6),
        batch=st.lists(
            st.tuples(st.integers(0, 5),           # slot (repeats likely)
                      st.integers(0, 1 << 22),     # length (0 hits x == 0)
                      st.integers(0, 1 << 24),     # queued_at_schedule
                      st.floats(0.0, 10.0)),       # t_obs
            min_size=1, max_size=32),
    )
    @settings(max_examples=150, deadline=None)
    def test_on_complete_many_bit_equals_scalar_loop(
            self, n_links, queues, beta0s, beta1s, batch):
        scalar, batched = _paired_stores(n_links, queues, beta0s, beta1s)
        items = [(slot % n_links, L, qas, tob) for slot, L, qas, tob in batch]
        for slot, L, qas, tob in items:
            scalar._views[slot].on_complete(L, qas, tob)
        batched.on_complete_many(
            np.asarray([i[0] for i in items], dtype=np.int64),
            np.asarray([i[1] for i in items], dtype=np.int64),
            np.asarray([i[2] for i in items], dtype=np.int64),
            np.asarray([i[3] for i in items], dtype=np.float64))
        for name in _COMPLETE_ARRS:
            a = getattr(scalar, name)[:scalar.n]
            b = getattr(batched, name)[:batched.n]
            assert (a == b).all(), f"{name}: {a} != {b}"

    @given(
        n_links=st.integers(1, 5),
        queues=st.lists(st.integers(0, 1 << 28), min_size=1, max_size=5),
        beta0s=st.lists(st.floats(0.0, 1e-2), min_size=1, max_size=5),
        beta1s=st.lists(st.floats(0.05, 50.0), min_size=1, max_size=5),
        batch=st.lists(
            st.tuples(st.integers(0, 4), st.integers(0, 1 << 22),
                      st.integers(0, 1 << 24), st.floats(0.0, 10.0)),
            min_size=1, max_size=16),
    )
    @settings(max_examples=40, deadline=None)
    def test_jnp_scan_twin_matches_numpy(
            self, n_links, queues, beta0s, beta1s, batch):
        """`tent_on_complete_many_jnp` under x64 replays the same update."""
        from jax.experimental import enable_x64

        from repro.core.scheduler import tent_on_complete_many_jnp

        ref, _ = _paired_stores(n_links, queues, beta0s, beta1s)
        items = [(slot % n_links, L, qas, tob) for slot, L, qas, tob in batch]
        n = ref.n
        state = {name: getattr(ref, name)[:n].copy()
                 for name in ("beta0_arr", "beta1_arr", "queued_arr",
                              "ewma_service_arr", "completions_arr",
                              "ewma_alpha_arr", "beta0_alpha_arr",
                              "bandwidth_arr")}
        for slot, L, qas, tob in items:
            ref._views[slot].on_complete(L, qas, tob)
        with enable_x64():
            b0, b1, q, ew, comp = tent_on_complete_many_jnp(
                state["beta0_arr"], state["beta1_arr"],
                state["queued_arr"], state["ewma_service_arr"],
                state["completions_arr"], state["ewma_alpha_arr"],
                state["beta0_alpha_arr"], state["bandwidth_arr"],
                np.asarray([i[0] for i in items]),
                np.asarray([i[1] for i in items]),
                np.asarray([i[2] for i in items]),
                np.asarray([i[3] for i in items], dtype=np.float64))
            b0, b1, q = np.asarray(b0), np.asarray(b1), np.asarray(q)
            ew, comp = np.asarray(ew), np.asarray(comp)
        assert (b0 == ref.beta0_arr[:n]).all()
        assert (b1 == ref.beta1_arr[:n]).all()
        assert (q == ref.queued_arr[:n]).all()
        assert (ew == ref.ewma_service_arr[:n]).all()
        assert (comp == ref.completions_arr[:n]).all()


class TestEwmaBounded:
    @given(
        obs=st.lists(st.floats(1e-7, 10.0), min_size=1, max_size=50),
        length=st.integers(1, 1 << 24),
    )
    @settings(max_examples=100, deadline=None)
    def test_beta_stays_positive_finite(self, obs, length):
        tl = _mk_tl(0)
        for t_obs in obs:
            tl.on_schedule(length)
            tl.on_complete(length, tl.queued_bytes + length, t_obs)
            assert np.isfinite(tl.beta0) and tl.beta0 >= 0
            assert np.isfinite(tl.beta1) and 0.05 <= tl.beta1 <= 1e4
            assert tl.queued_bytes >= 0
        tl.reset()
        assert tl.beta1 == 1.0 and tl.beta0 == tl.beta0_prior


class TestEndToEndIntegrity:
    @given(
        length=st.integers(1, 4 << 20),
        src_off=st.integers(0, 1 << 16),
        dst_off=st.integers(0, 1 << 16),
        seed=st.integers(0, 2 ** 16),
        policy=st.sampled_from(["tent", "round_robin", "static_best2", "pinned"]),
    )
    @settings(max_examples=20, deadline=None)
    def test_bytes_conserved_any_policy(self, length, src_off, dst_off, seed, policy):
        from repro.core import EngineConfig

        eng = TentEngine(FabricSpec(), config=EngineConfig(policy=policy), seed=seed)
        size = length + max(src_off, dst_off) + 1
        src = eng.register_segment(Location(node=0, kind=MemoryKind.HOST_DRAM), size)
        dst = eng.register_segment(Location(node=1, kind=MemoryKind.HOST_DRAM), size)
        payload = np.random.default_rng(seed).integers(0, 256, length, dtype=np.uint8)
        src.write(src_off, payload)
        res = eng.transfer_sync(src.segment_id, src_off, dst.segment_id, dst_off, length)
        assert res.ok
        np.testing.assert_array_equal(dst.read(dst_off, length), payload)
        # fabric conservation: rdma bytes moved >= payload (retries may add)
        moved = sum(
            l.bytes_completed for l in eng.fabric.links.values()
            if l.desc.node == 0 and l.desc.link_class.value in ("rdma", "tcp")
        )
        assert moved >= length


def _store_pair(n_links, queues, beta0s, beta1s):
    a, b = _paired_stores(n_links, queues, beta0s, beta1s)
    return a, b


class TestJitCoreKernelParity:
    """The fixed-shape kernels behind `repro.core.jit_core` vs their scalar
    references, over hypothesis-randomized batches: shape-bucket padding
    (inf-penalty candidate rows, invalid slice rows, the scratch drain
    slot) must be behaviorally invisible and every output bit-equal."""

    @given(
        queues=st.lists(st.integers(0, 1 << 28), min_size=1, max_size=9),
        pens=st.lists(st.sampled_from([1.0, 1.5, 3.0, np.inf]),
                      min_size=9, max_size=9),
        excluded=st.lists(st.booleans(), min_size=9, max_size=9),
        lengths=st.lists(st.integers(1, 1 << 20), min_size=1, max_size=20),
        rr=st.integers(0, 500),
        gamma=st.sampled_from([0.0, 0.05, 0.2]),
    )
    @settings(max_examples=60, deadline=None)
    def test_padded_choose_matches_scalar(
            self, queues, pens, excluded, lengths, rr, gamma):
        """`tent_choose_wave_padded_jnp` on bucketed shapes vs the scalar
        `tent_choose_wave` — choices, line-11 charges, queue write-back and
        round-robin cursor, including all-excluded fallback draws."""
        from jax.experimental import enable_x64

        from repro.core.jit_core import _bucket
        from repro.core.scheduler import tent_choose_wave_padded_jnp

        n_c, n_s = len(queues), len(lengths)
        q = np.asarray(queues, dtype=np.float64)
        gl = gr = np.zeros(n_c)
        bw = np.full(n_c, 25e9)
        b0, b1 = np.zeros(n_c), np.ones(n_c)
        pen = np.asarray(pens[:n_c], dtype=np.float64)
        ex = np.asarray(excluded[:n_c], dtype=bool)
        ln = np.asarray(lengths, dtype=np.float64)
        ref = tent_choose_wave(q, gl, gr, bw, b0, b1, pen, ex, ln, rr,
                               gamma=gamma)
        pc, ps = _bucket(n_c), _bucket(n_s)

        def pad(a, n, fill, dtype=np.float64):
            out = np.full(n, fill, dtype=dtype)
            out[: len(a)] = a
            return out

        valid = np.zeros(ps, dtype=bool)
        valid[:n_s] = True
        with enable_x64():
            c, qa, qo, rro = tent_choose_wave_padded_jnp(
                pad(q, pc, 0.0), pad(gl, pc, 0.0), pad(gr, pc, 0.0),
                pad(bw, pc, 1.0), pad(b0, pc, 0.0), pad(b1, pc, 1.0),
                pad(pen, pc, np.inf), pad(ex, pc, True, dtype=bool),
                pad(ln, ps, 0.0), valid, rr, gamma)
            got = (np.asarray(c)[:n_s], np.asarray(qa)[:n_s],
                   np.asarray(qo)[:n_c], int(rro))
        for r, g, label in zip(ref, got,
                               ("choices", "queued_at", "queued", "rr")):
            assert np.array_equal(np.asarray(r), np.asarray(g)), label

    @given(
        n_links=st.integers(1, 5),
        queues=st.lists(st.integers(0, 1 << 28), min_size=1, max_size=5),
        beta0s=st.lists(st.floats(0.0, 1e-2), min_size=1, max_size=5),
        beta1s=st.lists(st.floats(0.05, 50.0), min_size=1, max_size=5),
        batch=st.lists(
            st.tuples(st.integers(0, 4), st.integers(0, 1 << 22),
                      st.integers(0, 1 << 24), st.floats(0.0, 10.0)),
            min_size=1, max_size=24),
    )
    @settings(max_examples=40, deadline=None)
    def test_padded_drain_adapter_matches_store(
            self, n_links, queues, beta0s, beta1s, batch):
        """`EngineJitCore.on_complete_many` (gather -> padded jitted scan
        with the scratch-row batch padding -> scatter) vs the numpy store
        drain, heavy slot repetition included."""
        from repro.core.jit_core import EngineJitCore

        class _Policy:  # the drain path only touches the store
            _rr = 0
            gamma = 0.05

        a, b = _store_pair(n_links, queues, beta0s, beta1s)
        slots = np.asarray([i[0] % n_links for i in batch], dtype=np.int64)
        lengths = np.asarray([i[1] for i in batch], dtype=np.int64)
        qas = np.asarray([i[2] for i in batch], dtype=np.int64)
        tob = np.asarray([i[3] for i in batch], dtype=np.float64)
        a.on_complete_many(slots, lengths, qas, tob)
        EngineJitCore(_Policy(), b).on_complete_many(slots, lengths, qas, tob)
        for name in ("beta0_arr", "beta1_arr", "queued_arr",
                     "ewma_service_arr", "completions_arr"):
            x, y = getattr(a, name)[:a.n], getattr(b, name)[:b.n]
            assert (x == y).all(), f"{name}: {x} != {y}"

    @given(
        seed_index=st.integers(0, 2 ** 16),
        policy=st.sampled_from(["tent", "round_robin"]),
        fault_jitter=st.sampled_from([0.0, 0.25, 0.5]),
    )
    @settings(max_examples=10, deadline=None)
    def test_fused_sim_matches_numpy_ref(self, seed_index, policy,
                                         fault_jitter):
        """The fused lax.scan spray simulate vs its sequential numpy twin
        on the flap program: one compiled shape, randomized seeds/jitter,
        every scalar output bit-equal."""
        from repro.core import jit_core
        from repro.scenarios import get
        from repro.scenarios.sweep import compile_spray_program

        spec = get("single_rail_flap")
        program = compile_spray_program(spec)
        draws = jit_core.make_draws(program, base_seed=spec.seed,
                                    seed_index=seed_index)
        ref = jit_core.simulate_spray_ref(
            program, draws, policy=policy, fault_jitter=fault_jitter)
        got = jit_core.spray_single(
            program, base_seed=spec.seed, seed_index=seed_index,
            policy=policy, fault_jitter=fault_jitter)
        assert tuple(ref) == tuple(got)


class TestCalendarQueueOrdering:
    """Hypothesis twin of the seeded sweep in tests/test_calendar_parity.py:
    the bucketed timestamp wheel must pop in exact `heapq` order — the
    bit-parity contract the calendar-queue fabric event loop rests on."""

    @given(
        times=st.lists(
            st.floats(0.0, 1e6, allow_nan=False, allow_infinity=False),
            min_size=1, max_size=200),
        width=st.sampled_from([1e-6, 1e-3, 1.0]),
        threshold=st.sampled_from([4, 64, 4096]),
        tie_every=st.integers(1, 8),
    )
    @settings(max_examples=150, deadline=None)
    def test_pop_order_matches_heapq(self, times, width, threshold, tie_every):
        import heapq

        from repro.core import CalendarQueue

        # force timestamp collisions: every tie_every-th entry reuses the
        # previous time, exercising the in-bucket (time, seq) tie break
        entries = []
        for i, t in enumerate(times):
            if i % tie_every == 0 and entries:
                t = entries[-1][0]
            entries.append((t, i, f"e{i}"))
        cal = CalendarQueue(width, resize_threshold=threshold)
        heap = []
        for e in entries:
            cal.push(e)
            heapq.heappush(heap, e)
        got = [cal.pop() for _ in range(len(entries))]
        want = [heapq.heappop(heap) for _ in range(len(entries))]
        assert got == want
        assert len(cal) == 0

    @given(
        rounds=st.lists(
            st.tuples(st.lists(st.floats(0.0, 0.05, allow_nan=False),
                               min_size=0, max_size=8),
                      st.integers(0, 8)),
            min_size=1, max_size=30),
    )
    @settings(max_examples=100, deadline=None)
    def test_interleaved_monotonic_matches_heapq(self, rounds):
        """The fabric's access pattern: pushes land at-or-after the last
        popped time (the clock is monotonic), interleaved with drains."""
        import heapq

        from repro.core import CalendarQueue

        cal = CalendarQueue(1e-3)
        heap = []
        now, seq = 0.0, 0
        for deltas, pops in rounds:
            for d in deltas:
                e = (now + d, seq, seq)
                seq += 1
                cal.push(e)
                heapq.heappush(heap, e)
            for _ in range(pops):
                if not heap:
                    break
                want = heapq.heappop(heap)
                assert cal.pop() == want
                now = want[0]
        while heap:
            assert cal.pop() == heapq.heappop(heap)
