"""Training substrate: optimizer math, data determinism, loss-goes-down,
checkpoint round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.training import (
    AdamWConfig,
    DataConfig,
    SyntheticTokens,
    adamw_update,
    init_opt_state,
    load_checkpoint,
    lr_schedule,
    save_checkpoint,
    train,
)


class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=100)
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = init_opt_state(params)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}
            params, state, aux = adamw_update(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_grad_clip(self):
        cfg = AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0)
        params = {"w": jnp.zeros(4)}
        state = init_opt_state(params)
        grads = {"w": jnp.full(4, 1e6)}
        _, _, aux = adamw_update(cfg, params, grads, state)
        assert float(aux["grad_norm"]) > 1e5  # reported pre-clip

    def test_lr_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
        assert float(lr_schedule(cfg, jnp.int32(5))) < 1.0
        assert abs(float(lr_schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
        assert abs(float(lr_schedule(cfg, jnp.int32(110))) - 0.1) < 1e-3


class TestData:
    def test_deterministic_and_sharded(self):
        dc = DataConfig(vocab_size=1000, seq_len=64, batch_size=2, seed=7)
        a = SyntheticTokens(dc, shard=0, num_shards=2).example(3)
        b = SyntheticTokens(dc, shard=0, num_shards=2).example(3)
        c = SyntheticTokens(dc, shard=1, num_shards=2).example(3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        assert not np.array_equal(a["tokens"], c["tokens"])
        # next-token alignment
        np.testing.assert_array_equal(a["tokens"][:, 1:], a["targets"][:, :-1])
        assert a["tokens"].max() < 1000


class TestTrainLoop:
    @pytest.mark.slow
    def test_loss_decreases_smoke_model(self):
        cfg = get_smoke_config("qwen2-0.5b")
        res = train(cfg, steps=12, batch_size=2, seq_len=64, log=lambda s: None)
        first = np.mean(res.losses[:3])
        last = np.mean(res.losses[-3:])
        assert last < first, res.losses

    def test_checkpoint_roundtrip(self, tmp_path):
        cfg = get_smoke_config("qwen2-0.5b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        path = os.path.join(tmp_path, "ckpt.npz")
        save_checkpoint(path, params, opt)
        p2, o2 = load_checkpoint(path, params, opt)
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert int(o2["step"]) == int(opt["step"])
