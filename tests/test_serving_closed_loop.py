"""Event-driven serving closed loop: async/sync parity, transfer/compute
overlap, SLO surfacing through the scenario runner, and the serving-tier
bugfix-sweep regressions (pinned-set threading in nested eviction, PagePool
free hardening, checkpoint-table validation, simulator clock guards)."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import FabricSpec, TentEngine
from repro.scenarios import (
    Expectations,
    ScenarioSpec,
    ServingWorkload,
    names,
    run_scenario,
)
from repro.serving import (
    CheckpointEngine,
    HiCache,
    ServeSimConfig,
    ServingSimulator,
    from_table2,
    kv_bytes_per_token,
    make_cpu_pool,
    make_disk_pool,
    make_gpu_pool,
)


def _hicache(engine, cfg, *, gpu_pages, cpu_pages, disk_pages=0, page_tokens=16):
    pb = kv_bytes_per_token(cfg) * page_tokens
    return HiCache(
        engine,
        cfg,
        gpu_pool=make_gpu_pool(
            engine, 0, 0, page_bytes=pb, num_pages=gpu_pages, materialize=False),
        cpu_pool=make_cpu_pool(
            engine, 1, page_bytes=pb, num_pages=cpu_pages, materialize=False),
        disk_pool=(
            make_disk_pool(
                engine, 1, page_bytes=pb, num_pages=disk_pages, materialize=False)
            if disk_pages else None),
        page_tokens=page_tokens,
    )


def _seeded_cache(engine, cfg, sim_cfg, *, gpu_pages=64, cpu_pages=64):
    """A cache already holding every client's first-turn prefix in the CPU
    tier, so turn 1 fetches are real cross-fabric promotions."""
    hc = _hicache(engine, cfg, gpu_pages=gpu_pages, cpu_pages=cpu_pages)
    rng = np.random.default_rng(sim_cfg.seed)
    for _ in range(sim_cfg.clients):
        convo = rng.integers(
            1, 50_000, size=sim_cfg.turns * sim_cfg.input_tokens).tolist()
        hc.insert(convo[: sim_cfg.input_tokens])
    for e in list(hc.index.values()):
        hc._demote(e)
    assert hc.tier_counts()["gpu"] == 0
    return hc


class TestAsyncSyncParity:
    def test_concurrency_one_matches_sync(self):
        """At concurrency 1 nothing can overlap, so the event-driven loop must
        reproduce the analytical loop's numbers exactly (same promotions, same
        TTFTs, same makespan) — the closed loop changes *scheduling*, not
        physics."""
        cfg = get_smoke_config("qwen2-0.5b")
        perf = from_table2()
        stats = {}
        for mode in ("sync", "async"):
            sim_cfg = ServeSimConfig(
                clients=2, concurrency=1, turns=2, input_tokens=32,
                output_tokens=8, mode=mode)
            eng = TentEngine(FabricSpec())
            hc = _seeded_cache(eng, cfg, sim_cfg)
            stats[mode] = ServingSimulator(
                eng, perf, hicache=hc, sim_cfg=sim_cfg).run()
            assert hc.bytes_promoted > 0  # the fetches really crossed the wire
        s, a = stats["sync"], stats["async"]
        assert a.total_input_tokens == s.total_input_tokens
        assert a.bytes_promoted == s.bytes_promoted
        # fp accumulation order differs (callback chains vs one running sum)
        assert np.isclose(a.makespan, s.makespan, rtol=1e-7)
        assert np.isclose(a.avg_ttft, s.avg_ttft, rtol=1e-7)
        assert np.isclose(a.p99_ttft, s.p99_ttft, rtol=1e-7)
        assert np.isclose(a.input_throughput, s.input_throughput, rtol=1e-7)


class TestOverlap:
    def _pd_cfg(self, concurrency, cfg):
        return ServeSimConfig(
            clients=4, concurrency=concurrency, turns=1, input_tokens=256,
            output_tokens=16, mode="async", chunk_tokens=64, decode_chunk=4,
            handoff_bytes_per_token=kv_bytes_per_token(cfg))

    def test_concurrent_requests_overlap_on_the_fabric(self):
        """With concurrency > 1 the PD handoff flows and the decode compute of
        different requests run at the same virtual time: the makespan lands
        strictly below the sum of un-overlapped service times, and strictly
        below the concurrency-1 makespan of the same offered load."""
        cfg = get_smoke_config("qwen2-0.5b")
        perf = from_table2()
        mk = {}
        for concurrency in (1, 4):
            eng = TentEngine(FabricSpec())
            st = ServingSimulator(
                eng, perf, hicache=None,
                sim_cfg=self._pd_cfg(concurrency, cfg)).run()
            mk[concurrency] = st.makespan
            assert st.bytes_handoff > 0
            if concurrency > 1:
                assert st.makespan < st.serialized_seconds
        assert mk[4] < mk[1]

    def test_serialized_seconds_bounds_concurrency_one(self):
        # with one slot nothing overlaps: makespan ~= serialized sum
        cfg = get_smoke_config("qwen2-0.5b")
        eng = TentEngine(FabricSpec())
        st = ServingSimulator(
            eng, from_table2(), hicache=None,
            sim_cfg=self._pd_cfg(1, cfg)).run()
        assert np.isclose(st.makespan, st.serialized_seconds, rtol=1e-6)


class TestCheckpointOverlapMode:
    def test_update_async_delivers_result(self):
        eng = TentEngine(FabricSpec())
        ce = CheckpointEngine(eng, nodes=2, gpus_per_node=2, materialize=False)
        ce.register_checkpoint({"w": 8 << 20})
        got = []
        ce.update_async(got.append)
        assert not got  # asynchronous: nothing lands before the fabric runs
        eng.run_until_idle()
        assert len(got) == 1
        assert got[0].seconds > 0
        assert got[0].bytes == ce.total_bytes
        assert got[0].ranks == 4

    def test_serving_loop_runs_overlapped_updates(self):
        cfg = get_smoke_config("qwen2-0.5b")
        eng = TentEngine(FabricSpec())
        ce = CheckpointEngine(eng, nodes=2, gpus_per_node=2, materialize=False)
        ce.register_checkpoint({"w": 32 << 20})
        sim_cfg = ServeSimConfig(
            clients=3, concurrency=2, turns=2, input_tokens=64,
            output_tokens=8, mode="async", checkpoint_updates=2)
        st = ServingSimulator(
            eng, from_table2(), hicache=None, sim_cfg=sim_cfg,
            checkpoint=ce).run()
        assert st.checkpoint_updates == 2
        assert st.checkpoint_seconds > 0


class TestServingScenarios:
    def test_library_has_serving_scenarios(self):
        got = set(names())
        for name in ("serving_closed_loop_flap", "serving_pd_handoff_incast",
                     "serving_checkpoint_overlap"):
            assert name in got

    def test_workload_round_trips(self):
        spec = ScenarioSpec(
            name="rt", workload=ServingWorkload(clients=3, pd_handoff=True))
        again = ScenarioSpec.from_dict(spec.to_dict())
        assert again.workload == spec.workload

    def test_slo_violations_surface_in_report(self):
        spec = ScenarioSpec(
            name="impossible_slo",
            workload=ServingWorkload(
                clients=2, concurrency=2, turns=1, input_tokens=256,
                output_tokens=4, chunk_tokens=128, decode_chunk=4),
            expectations=Expectations(
                max_ttft_p99_s=1e-9, max_tpot_p99_s=1e-9),
        )
        rep = run_scenario(spec)
        assert not rep.ok
        assert any("TTFT P99" in v for v in rep.violations)
        assert any("TPOT P99" in v for v in rep.violations)


class TestPinnedEvictionRegression:
    """_demote must thread the pinned set into the nested _make_room: a
    GPU->CPU demotion that itself evicts from the CPU tier could otherwise
    delete a page of the very chain being fetched (then double-free it when
    the fetch rebinds)."""

    def _setup(self, cpu_pages):
        cfg = get_smoke_config("qwen2-0.5b")
        eng = TentEngine(FabricSpec())
        hc = _hicache(eng, cfg, gpu_pages=2, cpu_pages=cpu_pages)
        chain = list(range(32))  # 2 pages
        hc.insert(chain)
        for e in list(hc.index.values()):
            hc._demote(e)  # chain now lives on the CPU tier
        hc.insert(list(range(1000, 1032)))  # fills the GPU tier
        return hc, chain

    def test_nested_eviction_cascades_without_touching_the_chain(self):
        # CPU has one spare page: promoting the chain forces GPU->CPU
        # demotions whose nested CPU evictions must pick the *other* resident
        hc, chain = self._setup(cpu_pages=3)
        keys = set(hc._prefix_keys(chain))
        res = hc.fetch_prefix(chain)
        assert res.promoted_pages == 2
        assert all(k in hc.index and hc.index[k].tier == "gpu" for k in keys)

    def test_full_cpu_tier_refuses_rather_than_evicting_the_chain(self):
        # CPU holds only the pinned chain: the nested eviction has no legal
        # victim and must fail loudly instead of deleting a chain entry
        hc, chain = self._setup(cpu_pages=2)
        keys = set(hc._prefix_keys(chain))
        with pytest.raises(RuntimeError, match="too small"):
            hc.fetch_prefix(chain)
        # the chain survived intact — nothing was freed or rebound
        assert all(k in hc.index and hc.index[k].tier == "cpu" for k in keys)

    def test_async_fetch_pins_chain_until_bytes_land(self):
        cfg = get_smoke_config("qwen2-0.5b")
        eng = TentEngine(FabricSpec())
        hc = _hicache(eng, cfg, gpu_pages=4, cpu_pages=4)
        chain = list(range(32))
        hc.insert(chain)
        for e in list(hc.index.values()):
            hc._demote(e)
        done = []
        hc.fetch_prefix_async(chain, done.append)
        assert not done  # promotion still on the wire
        entries = [hc.index[k] for k in hc._prefix_keys(chain)]
        assert all(e.pins == 1 for e in entries)
        with pytest.raises(RuntimeError, match="too small"):
            hc._victim("gpu", frozenset())  # pinned entries are not victims
        eng.run_until_idle()
        assert done and done[0].promoted_pages == 2
        assert done[0].transfer_seconds > 0
        assert all(e.pins == 0 for e in entries)


class TestPagePoolHardening:
    def _pool(self):
        eng = TentEngine(FabricSpec())
        cfg = get_smoke_config("qwen2-0.5b")
        pb = kv_bytes_per_token(cfg) * 16
        a = make_gpu_pool(eng, 0, 0, page_bytes=pb, num_pages=4,
                          materialize=False)
        b = make_cpu_pool(eng, 1, page_bytes=pb, num_pages=4,
                          materialize=False)
        return a, b

    def test_double_free_raises(self):
        pool, _ = self._pool()
        page = pool.alloc()
        pool.free(page)
        with pytest.raises(ValueError, match="double free"):
            pool.free(page)

    def test_stale_free_after_slot_reuse_raises(self):
        pool, _ = self._pool()
        old = pool.alloc()
        pool.free(old)
        fresh = pool.alloc()  # reuses the slot under a new page id
        with pytest.raises(ValueError, match="double free"):
            pool.free(old)
        pool.free(fresh)  # the live page still frees cleanly

    def test_foreign_page_raises(self):
        pool_a, pool_b = self._pool()
        page = pool_a.alloc()
        with pytest.raises(ValueError, match="belongs to"):
            pool_b.free(page)
        pool_a.free(page)  # unharmed

    def test_free_then_realloc_cycles(self):
        pool, _ = self._pool()
        for _ in range(3):
            pages = [pool.alloc() for _ in range(4)]
            assert pool.alloc() is None  # exhausted
            for p in pages:
                pool.free(p)
        assert pool.free_pages == 4


class TestCheckpointRegistration:
    def test_empty_table_rejected(self):
        eng = TentEngine(FabricSpec())
        ce = CheckpointEngine(eng, nodes=1, gpus_per_node=2, materialize=False)
        with pytest.raises(ValueError, match="empty checkpoint table"):
            ce.register_checkpoint({})

    def test_zero_byte_table_rejected(self):
        eng = TentEngine(FabricSpec())
        ce = CheckpointEngine(eng, nodes=1, gpus_per_node=2)
        with pytest.raises(ValueError, match="zero bytes"):
            ce.register_checkpoint({
                "a": np.zeros(0, np.uint8), "b": np.zeros(0, np.float32)})

    def test_zero_byte_entry_among_real_ones_is_fine(self):
        eng = TentEngine(FabricSpec())
        ce = CheckpointEngine(eng, nodes=1, gpus_per_node=2)
        ce.register_checkpoint({
            "empty": np.zeros(0, np.uint8),
            "w": np.arange(1 << 16, dtype=np.uint8),
        })
        res = ce.update(verify=True)
        assert res.seconds > 0
        assert res.bytes >= 1 << 16


class TestSimulatorGuards:
    @pytest.mark.parametrize("mode", ["sync", "async"])
    @pytest.mark.parametrize("clients,turns", [(0, 3), (3, 0)])
    def test_empty_run_returns_zeroed_stats(self, mode, clients, turns):
        eng = TentEngine(FabricSpec())
        st = ServingSimulator(
            eng, from_table2(), hicache=None,
            sim_cfg=ServeSimConfig(clients=clients, turns=turns, mode=mode),
        ).run()
        assert st.input_throughput == 0.0
        assert st.makespan == 0.0
        assert st.total_input_tokens == 0
        assert st.request_log == []

    def test_sync_clock_stays_monotone_under_slow_fetches(self):
        """Promotion transfers advance the fabric past later slots' computed
        start times; the sim must clamp instead of asking the virtual clock to
        run backwards."""
        cfg = get_smoke_config("qwen2-0.5b")
        sim_cfg = ServeSimConfig(
            clients=3, concurrency=2, turns=2, input_tokens=32,
            output_tokens=4, mode="sync")
        eng = TentEngine(FabricSpec())
        hc = _seeded_cache(eng, cfg, sim_cfg, gpu_pages=6, cpu_pages=16)
        st = ServingSimulator(eng, from_table2(), hicache=hc,
                              sim_cfg=sim_cfg).run()
        assert len(st.request_log) == 6
        assert st.makespan > 0
        assert all(t >= 0 for t, _, _ in st.request_log)
