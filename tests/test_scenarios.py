"""Fast deterministic regression tier over the named scenario library.

Runs every scenario in `repro.scenarios.SCENARIOS` once (virtual clock, fixed
seeds, <10 s wall for the whole matrix) and asserts the paper's invariants
via each spec's declared expectations: TENT at least matches every baseline,
fault scenarios recover within the virtual 50 ms budget, no slice is ever
lost, and the spray stays balanced where the fabric is symmetric.
"""
import dataclasses

import numpy as np
import pytest

from repro.scenarios import (
    SCENARIOS,
    ClosedLoopWorkload,
    Expectations,
    FaultEvent,
    ScenarioRunner,
    ScenarioSpec,
    TopologyParams,
    flap_storm,
    get,
)


@pytest.fixture(scope="module")
def reports():
    """One run of the whole library, shared by the per-scenario asserts."""
    return {name: ScenarioRunner(spec).run() for name, spec in SCENARIOS.items()}


@pytest.mark.parametrize("name", sorted(SCENARIOS))
class TestScenarioMatrix:
    def test_expectations_hold(self, reports, name):
        rep = reports[name]
        assert rep.ok, f"{name} violated its spec: {rep.violations}"

    def test_zero_lost_slices_and_no_app_failures(self, reports, name):
        for policy, r in reports[name].policies.items():
            assert r.lost_slices == 0, (name, policy)
            assert r.batches_failed == 0, (name, policy)
            assert r.ok

    def test_report_round_trips_to_json(self, reports, name):
        import json

        d = reports[name].to_dict()
        assert json.loads(reports[name].to_json()) == json.loads(json.dumps(d))


class TestPaperInvariants:
    """The named claims, asserted directly (not only via the spec)."""

    def test_tent_leads_every_ablation(self, reports):
        for name, rep in reports.items():
            spec = SCENARIOS[name]
            factor = spec.expectations.tent_vs_baseline
            prim = rep.policies[spec.primary_policy]
            for p in spec.baseline_policies:
                assert prim.throughput >= factor * rep.policies[p].throughput, (
                    name, p, prim.throughput, rep.policies[p].throughput)

    def test_fault_scenarios_recover_within_virtual_50ms(self, reports):
        checked = 0
        for name, rep in reports.items():
            spec = SCENARIOS[name]
            if not any(f.kind == "fail" for f in spec.faults):
                continue
            prim = rep.policies[spec.primary_policy]
            assert 0 <= prim.stall_ms < 50.0, (name, prim.stall_ms)
            if spec.expectations.max_recovery_ms > 0:
                assert 0 <= prim.recovery_ms < 50.0, (name, prim.recovery_ms)
            checked += 1
        assert checked >= 4  # flap, storm, outage, disagg at minimum

    def test_symmetric_spray_is_balanced(self, reports):
        r = reports["uniform_spray"].policies["tent"]
        assert 1.0 <= r.rail_imbalance <= 1.35
        # every rail on the sending node carried bytes
        active = [b for name, b in r.bytes_by_rail.items() if name.startswith("n0/")]
        assert len(active) == 8 and all(b > 0 for b in active)

    def test_fault_scenarios_actually_retried(self, reports):
        r = reports["single_rail_flap"].policies["tent"]
        assert r.retries > 0 and r.exclusions > 0


class TestDeterminism:
    def test_same_spec_same_report(self):
        spec = get("checkpoint_broadcast")
        a = ScenarioRunner(spec).run().to_dict()
        b = ScenarioRunner(spec).run().to_dict()
        assert a == b

    def test_seed_matters_but_is_pinned(self):
        spec = get("uniform_spray")
        base = ScenarioRunner(spec).run_policy("tent")
        reseeded = ScenarioRunner(dataclasses.replace(spec, seed=123)).run_policy("tent")
        # different jitter stream, same invariants
        assert reseeded.lost_slices == 0
        assert np.isclose(reseeded.throughput, base.throughput, rtol=0.2)


class TestSpecRoundTrip:
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_library_round_trips(self, name):
        spec = SCENARIOS[name]
        assert ScenarioSpec.from_json(spec.to_json()) == spec
        assert ScenarioSpec.from_dict(spec.to_dict()) == spec

    def test_defaults_round_trip(self):
        spec = ScenarioSpec(name="t")
        assert ScenarioSpec.from_dict(spec.to_dict()) == spec

    def test_workload_kinds_dispatch(self):
        spec = get("checkpoint_broadcast")
        d = spec.to_dict()
        assert d["workload"]["kind"] == "checkpoint"
        assert ScenarioSpec.from_dict(d).workload == spec.workload

    def test_bad_fault_kind_rejected(self):
        with pytest.raises(ValueError):
            FaultEvent("melt", 0, 0, at=0.0, until=1.0)
        with pytest.raises(ValueError):
            FaultEvent("fail", 0, 0, at=1.0, until=1.0)

    def test_unknown_scenario_name(self):
        with pytest.raises(KeyError):
            get("no_such_scenario")


class TestGenerators:
    def test_flap_storm_windows_are_disjoint(self):
        events = flap_storm(0, 3, start=0.1, flaps=5, down=0.01, up=0.02)
        assert len(events) == 5
        for a, b in zip(events, events[1:]):
            assert a.until <= b.at
            assert b.nic == 3 and b.kind == "fail"

    def test_timed_workload_duration_is_clock_relative(self):
        """`duration` counts from the current virtual clock (regression: the
        cutoff once compared against the absolute clock, so a timed workload
        on a warmed-up engine returned instantly with zero completions)."""
        from repro.scenarios import drive_closed_loop

        spec = ScenarioSpec(name="warm", topology=TopologyParams(nic_bw=2.5e9))
        engine, _ = ScenarioRunner(spec).build_engine("tent")
        engine.fabric.run_until(1.0)  # clock already past any small duration
        from repro.scenarios import host_loc

        src = engine.register_segment(host_loc(0), 1 << 20, materialize=False)
        dst = engine.register_segment(host_loc(1), 1 << 20, materialize=False)
        out = drive_closed_loop(
            engine, [(src.segment_id, dst.segment_id, 1 << 20)],
            iters=0, duration=0.01)
        assert out.completions and out.makespan >= 0.01

    def test_custom_spec_runs(self):
        """A spec built from scratch (not the library) executes end to end."""
        spec = ScenarioSpec(
            name="adhoc",
            topology=TopologyParams(nic_bw=2.5e9),
            workload=ClosedLoopWorkload(streams=2, blocks=(1 << 20,), iters=4),
            policies=("tent",),
            expectations=Expectations(tent_vs_baseline=0.0),
        )
        rep = ScenarioRunner(spec).run()
        assert rep.ok
        r = rep.policies["tent"]
        assert r.requests == 8 and r.bytes_total == 8 << 20
        assert r.throughput > 0
